"""PlanStore conformance: canonical keying, persistence, schema/config
invalidation, the two-tier PlanCache, warm restarts, and cross-process
sharing (one store file, many controllers -- the fleet model).

Optimisations here all use the small closed-form demo cluster of
``tools/precompute_plans.py`` (the same fixtures CI's precomputed artifact is
built from), so every test that actually runs the optimiser costs well under
a second."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))  # tools/ is a plain directory, not a package

from repro.core import (
    PLAN_SCHEMA_VERSION,
    SCHEMES,
    PlanCache,
    PlanStore,
    ReplanController,
    canonical_key,
    key_hash,
)
from tools.precompute_plans import (
    demo_config,
    demo_net,
    demo_scheme_config,
    demo_topology,
    lattice_keys,
    precompute,
)

import dataclasses


def _controller(store=None, config=None):
    return ReplanController(
        demo_net(), demo_topology(),
        config if config is not None else demo_config(),
        store=store,
    )


# ---------------------------------------------------------------------------
# canonical_key: the serialisation the whole content-keying scheme rests on
# ---------------------------------------------------------------------------


def test_canonical_key_is_type_distinct():
    """Values that compare unequal in Python must never alias in the store:
    str vs int vs float vs bool vs None all serialise distinctly."""
    distinct = [
        ("1",), (1,), (1.0,), (True,), (None,),
        (("a",),), ("a",), ((),), (0.5,), (0.25 + 0.25,),
    ]
    texts = [canonical_key(k) for k in distinct]
    # (0.5,) and (0.25+0.25,) ARE the same float -- same text; all else differs
    assert texts[8] == texts[9]
    assert len(set(texts[:9])) == 9
    # equal keys always produce equal text and equal hashes
    key = (("plan", ("e0", ("a", "b")), 0.5), ((("e0", "a"), -3),))
    assert canonical_key(key) == canonical_key(key)
    assert key_hash(key) == key_hash(key)


def test_canonical_key_rejects_unsupported():
    with pytest.raises(TypeError):
        canonical_key(({"a": 1},))
    with pytest.raises(ValueError):
        canonical_key((float("inf"),))
    with pytest.raises(ValueError):
        canonical_key((float("nan"),))


# ---------------------------------------------------------------------------
# PlanStore: round trip, provenance, invalidation
# ---------------------------------------------------------------------------


def test_store_round_trip_bit_identical(tmp_path):
    """A stored OptimizeResult comes back *equal* -- same plan dataclass,
    same float makespan bits -- and provenance rides along."""
    ctrl = _controller()
    result = ctrl.current()
    key = (ctrl._fingerprint, ctrl._active)
    with PlanStore(tmp_path / "s.sqlite") as store:
        assert store.get(key) is None and store.misses == 1
        store.put(key, result, provenance={"engine": "batched", "note": "t"})
        loaded = store.get(key)
        assert loaded == result  # full dataclass equality, plan included
        assert loaded.makespan == result.makespan
        assert store.hits == 1 and store.writes == 1 and len(store) == 1
        prov = store.provenance(key)
        assert prov["engine"] == "batched" and prov["note"] == "t"
        assert prov["makespan"] == result.makespan
        assert prov["created_s"] > 0
        assert store.keys() == [canonical_key(key)]
        assert store.keys(kind="plan") == [canonical_key(key)]
        assert store.keys(kind="placement") == []


def test_store_schema_version_invalidation(tmp_path):
    """Rows written under another PLAN_SCHEMA_VERSION are never served, count
    as stale, and prune_stale garbage-collects them."""
    path = tmp_path / "s.sqlite"
    key = (("plan", "k"), (1,))
    with PlanStore(path) as store:
        store.put(key, ("payload",))
    with PlanStore(path, schema_version=PLAN_SCHEMA_VERSION + 1) as bumped:
        assert bumped.get(key) is None
        assert bumped.stale == 1 and bumped.misses == 1
        assert len(bumped) == 0  # the old row is invisible, not just unread
        assert bumped.prune_stale() == 1
    with PlanStore(path) as reopened:
        assert reopened.get(key) is None  # pruned for good


def test_store_hash_collision_never_serves_wrong_plan(tmp_path):
    """Even if two keys collided in sha256, the stored canonical text must
    veto the read (simulated by corrupting key_text in place)."""
    key = (("plan", "k"), (1,))
    with PlanStore(tmp_path / "s.sqlite") as store:
        store.put(key, ("payload",))
        store._conn.execute(
            "UPDATE plans SET key_text = ?", (canonical_key((("plan", "other"), (2,))),)
        )
        store._conn.commit()
        assert store.get(key) is None and store.misses == 1


def test_store_invalidate_by_kind(tmp_path):
    with PlanStore(tmp_path / "s.sqlite") as store:
        store.put((("plan", "a"), (1,)), 1)
        store.put((("placement", "b"), (2,)), 2)
        assert len(store) == 2
        assert store.invalidate(kind="placement") == 1
        assert store.keys(kind="placement") == []
        assert len(store) == 1
        assert store.invalidate() == 1
        assert len(store) == 0


# ---------------------------------------------------------------------------
# Two-tier PlanCache
# ---------------------------------------------------------------------------


def test_two_tier_cache_store_outlives_lru_eviction(tmp_path):
    """LRU eviction drops only the memory copy: the evicted key comes back
    as a store hit, and peek stays memory-only throughout."""
    with PlanStore(tmp_path / "s.sqlite") as store:
        cache = PlanCache(capacity=1, store=store)
        k1, k2 = (("plan", "x"), (1,)), (("plan", "x"), (2,))
        cache.put(k1, "r1")
        cache.put(k2, "r2")  # evicts k1 from memory; store keeps both
        assert cache.evictions == 1 and len(cache) == 1 and len(store) == 2
        assert cache.peek(k1) is None  # memory-only by design
        assert cache.get(k1) == "r1"  # served by the store...
        assert cache.store_hits == 1 and cache.hits == 1
        assert cache.peek(k1) == "r1"  # ...and promoted into memory
        # promotion did not write back: still exactly one write per put
        assert store.writes == 2
        # a genuine miss misses both tiers
        assert cache.get((("plan", "x"), (3,))) is None
        assert cache.misses == 1


def test_storeless_cache_counters_unchanged():
    """Without a store the two-tier cache is exactly the old LRU: same
    counters, same eviction behaviour (the pinned test_replan counts rely on
    this)."""
    cache = PlanCache(capacity=2)
    assert cache.store is None
    cache.put("a", 1)
    assert cache.get("a") == 1 and cache.get("b") is None
    assert (cache.hits, cache.misses, cache.store_hits) == (1, 1, 0)


# ---------------------------------------------------------------------------
# Controllers over a persistent store: warm starts, invalidation, sharing
# ---------------------------------------------------------------------------


def test_warm_restart_serves_first_plan_with_zero_optimizer_calls(tmp_path):
    path = tmp_path / "plans.sqlite"
    with PlanStore(path) as store:
        cold = _controller(store=store)
        r_cold = cold.current()
        assert cold.optimizer_calls == 1
        assert cold.stats()["store_entries"] == 1
    # the restart: new process model -- new connection, new controller
    with PlanStore(path) as store:
        warm = _controller(store=store)
        r_warm = warm.current()
        assert warm.optimizer_calls == 0  # the acceptance criterion
        assert warm.stats()["store_hits"] == 1
        assert r_warm == r_cold  # bit-identical result, plan and makespan
        assert r_warm.plan == r_cold.plan
        assert r_warm.makespan == r_cold.makespan


def test_optimizer_config_change_never_serves_stale_plan(tmp_path):
    path = tmp_path / "plans.sqlite"
    with PlanStore(path) as store:
        _controller(store=store).current()
    with PlanStore(path) as store:
        recfg = dataclasses.replace(demo_config(), max_rounds=demo_config().max_rounds + 1)
        ctrl = _controller(store=store, config=recfg)
        ctrl.current()
        assert ctrl.optimizer_calls == 1  # keyed differently => re-optimised
        assert ctrl.stats()["store_hits"] == 0
        assert len(store) == 2  # both configs' entries coexist


def test_prime_fills_store_without_adopting(tmp_path):
    with PlanStore(tmp_path / "plans.sqlite") as store:
        ctrl = _controller(store=store)
        active_before = ctrl._active
        keys = lattice_keys(ctrl, [-1, 0], [-1, 0])
        for k in keys:
            ctrl.prime(k)
        assert ctrl._active == active_before
        assert ctrl.optimizer_calls == len(keys)
        assert len(store) == len(keys)
        # priming again is free: all store/memory hits
        for k in keys:
            ctrl.prime(k)
        assert ctrl.optimizer_calls == len(keys)


def test_cross_process_sharing_one_store_file(tmp_path):
    """A store populated by a *different process* (the precompute tool run
    via subprocess) warm-starts a controller here: the whole lattice serves
    with zero optimizer calls -- for both the halo-only and the
    scheme-vocabulary controller."""
    path = tmp_path / "plans.sqlite"
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "precompute_plans.py"),
         "--store", str(path), "--smoke"],
        capture_output=True, text=True, cwd=str(REPO), timeout=300,
    )
    assert proc.returncode == 0, proc.stderr
    assert path.exists()
    with PlanStore(path) as store:
        assert len(store) == 12  # 3 x 3 halo lattice + 3-point scheme lattice
        ctrl = _controller(store=store)
        for key in lattice_keys(ctrl, [-1, 0, 1], [-2, -1, 0]):
            ctrl.prime(key)
        assert ctrl.optimizer_calls == 0
        assert ctrl.cache.store_hits == 9
        sctrl = _controller(store=store, config=demo_scheme_config())
        for key in lattice_keys(sctrl, [-1, 0, 1], [0]):
            sctrl.prime(key)
        assert sctrl.optimizer_calls == 0
        assert sctrl.cache.store_hits == 3


def test_two_controllers_share_one_store_live(tmp_path):
    """Two live controllers over separate connections to one file: what one
    optimises, the other reads -- no second optimisation."""
    path = tmp_path / "plans.sqlite"
    with PlanStore(path) as s1, PlanStore(path) as s2:
        a, b = _controller(store=s1), _controller(store=s2)
        a.current()
        b.current()
        assert a.optimizer_calls == 1 and b.optimizer_calls == 0
        assert b.cache.store_hits == 1
        assert b.plan == a.plan


def test_ci_artifact_store_warm(tmp_path):
    """Store-backed run against the CI-built artifact (set PLANSTORE_ARTIFACT
    to the uploaded file): every smoke-lattice point must serve warm, under
    both the halo-only and the scheme-vocabulary config."""
    artifact = os.environ.get("PLANSTORE_ARTIFACT")
    if not artifact or not Path(artifact).exists():
        pytest.skip("PLANSTORE_ARTIFACT not provided")
    with PlanStore(artifact) as store:
        ctrl = _controller(store=store)
        for key in lattice_keys(ctrl, [-1, 0, 1], [-2, -1, 0]):
            ctrl.prime(key)
        assert ctrl.optimizer_calls == 0, "artifact store must cover the smoke lattice"
        sctrl = _controller(store=store, config=demo_scheme_config())
        for key in lattice_keys(sctrl, [-1, 0, 1], [0]):
            sctrl.prime(key)
        assert sctrl.optimizer_calls == 0, "artifact must cover the scheme lattice"


def test_precompute_is_idempotent(tmp_path):
    path = str(tmp_path / "plans.sqlite")
    first = precompute(path, [-1, 0], [0])
    again = precompute(path, [-1, 0], [0])
    assert first["optimizer_calls"] == 2 and first["store_entries"] == 2
    assert again["optimizer_calls"] == 0 and again["already_stored"] == 2
    assert again["store_entries"] == 2


def test_precompute_scheme_lattice_idempotent_and_disjoint(tmp_path):
    """The scheme-vocabulary lattice is idempotent like the base walk, and
    keys disjointly: the same operating points under the halo-only config
    re-optimise rather than serving scheme-vocabulary plans (and vice
    versa)."""
    path = str(tmp_path / "plans.sqlite")
    first = precompute(path, [-1, 0], [0], config=demo_scheme_config())
    again = precompute(path, [-1, 0], [0], config=demo_scheme_config())
    assert first["optimizer_calls"] == 2 and first["store_entries"] == 2
    assert again["optimizer_calls"] == 0 and again["already_stored"] == 2
    halo = precompute(path, [-1, 0], [0])
    assert halo["optimizer_calls"] == 2  # zero hits from the scheme rows
    assert halo["store_entries"] == 4


def test_scheme_vocabulary_rekeys_but_engine_does_not(tmp_path):
    """An enlarged scheme vocabulary searches a bigger space, so it must be
    part of the plan key (a vocabulary change can never serve a halo-only
    optimum); the pricing `engine` stays excluded (bit-identical scores
    either way) -- the engine-exclusion contract, extended."""
    path = tmp_path / "plans.sqlite"
    base = dataclasses.replace(demo_config(), use_simulator=True, n_tasks=1)
    with PlanStore(path) as store:
        cold = _controller(store=store, config=base)
        r_base = cold.current()
        assert cold.optimizer_calls == 1
    with PlanStore(path) as store:
        vocab = dataclasses.replace(base, schemes=SCHEMES)
        ctrl = _controller(store=store, config=vocab)
        ctrl.current()
        assert ctrl.optimizer_calls == 1  # re-keyed: zero store hits
        assert ctrl.stats()["store_hits"] == 0
        assert len(store) == 2  # both vocabularies' entries coexist
    with PlanStore(path) as store:
        repriced = dataclasses.replace(base, engine="scalar")
        ctrl = _controller(store=store, config=repriced)
        r_warm = ctrl.current()
        assert ctrl.optimizer_calls == 0  # engine not in the key: warm hit
        assert ctrl.stats()["store_hits"] == 1
        assert r_warm == r_base
