"""Fault tolerance: checkpoint/restart training, straggler detection, and the
deadline model shared with the paper's §V-D reliability analysis.

Design for 1000+ nodes (DESIGN.md):
* **checkpoint/restart** -- the trainer checkpoints every K steps and replays
  the deterministic data stream from the restored step; any step-level failure
  (device error, injected fault) triggers restore-and-continue with bounded
  retries.
* **straggler mitigation** -- per-step wall-times feed an EMA; steps slower
  than ``straggler_factor`` x EMA are counted and surfaced, and (when wired)
  each newly completed step's timing feeds a ``compute_observer`` -- the
  per-ES compute-rate estimate of the online planner
  (``core.replan.ComputeRateEstimator``), so a straggling node triggers a
  joint re-plan instead of silently stretching every makespan.  At scale the
  launcher uses this signal to evict/replace slow hosts; the analytical twin
  (core.simulator slowdown injection + core.reliability deadlines) quantifies
  the effect on service deadlines, exactly as the paper does for time-variant
  channels.
* **elastic scaling** -- batches are pure functions of (seed, step) and
  checkpoints are mesh-agnostic (host npz), so a restore onto a *different*
  mesh (more or fewer pods) resumes bit-exactly; tests restore onto a fresh
  state to prove it.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax

from .checkpoint import latest_step, restore_checkpoint, save_checkpoint

__all__ = ["FaultConfig", "FaultTolerantTrainer", "InjectedFault"]


class InjectedFault(RuntimeError):
    """Raised by tests / chaos hooks to simulate node failure."""


@dataclass
class FaultConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    max_failures: int = 3
    straggler_factor: float = 2.5
    ema_alpha: float = 0.1


@dataclass
class TrainerStats:
    steps: int = 0
    failures: int = 0
    restores: int = 0
    stragglers: int = 0
    ema_step_s: float = 0.0
    losses: list = field(default_factory=list)


class FaultTolerantTrainer:
    """Wraps a jitted train step with checkpoint/restart + straggler stats.

    ``step_fn(state, **batch) -> (state, metrics)``; ``stream.batch_at(i)``
    must be deterministic in ``i`` (repro.data guarantees this).

    ``compute_observer`` closes the loop to the online planner: when set
    (together with ``step_flops``, the known FLOP count of one step), every
    *newly completed* step's wall-time is reported as
    ``compute_observer(es_name, step_flops, dt)`` -- wire
    ``ReplanController.observe_compute`` (or a bare
    :class:`~repro.core.replan.ComputeRateEstimator`'s ``observe``) here so
    this node straggling moves the planner's per-ES compute estimate.
    Replayed steps after a checkpoint restore are deduplicated by step index
    before reaching the stats *or* the observer, so a fault cannot double-feed
    either."""

    def __init__(self, step_fn: Callable, stream, cfg: FaultConfig,
                 fault_hook: Callable[[int], None] | None = None,
                 compute_observer: Callable[[str, float, float], None] | None = None,
                 es_name: str = "host",
                 step_flops: float | None = None):
        self.step_fn = step_fn
        self.stream = stream
        self.cfg = cfg
        self.fault_hook = fault_hook
        self.compute_observer = compute_observer
        self.es_name = es_name
        self.step_flops = step_flops
        self.stats = TrainerStats()
        self._tracked_upto = 0  # stats watermark: first step index not yet counted

    def _maybe_restore(self, state):
        step = latest_step(self.cfg.ckpt_dir)
        if step is None:
            return state, 0
        state, step, _ = restore_checkpoint(self.cfg.ckpt_dir, state)
        self.stats.restores += 1
        return state, step

    def run(self, state, n_steps: int, start_step: int = 0, resume: bool = True):
        if resume:
            state, start_step = self._maybe_restore(state)
        # steps below the run's start are genuinely re-executed (e.g. a fresh
        # resume=False run on a reused trainer), not replayed -- lower the
        # stats watermark so they count; within-run replays stay deduped
        self._tracked_upto = min(self._tracked_upto, start_step)
        # Snapshot the entry state (jax pytrees are immutable, so holding the
        # reference is a true snapshot): recovering from a fault *before the
        # first checkpoint exists* must rewind the state together with the
        # step index -- rewinding only ``i`` would re-apply already-consumed
        # batches to an already-advanced state, silently corrupting the run.
        entry_state = state
        i = start_step
        high_water = start_step  # furthest step ever completed this run
        consecutive_failures = 0
        while i < n_steps:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(i)  # chaos injection point
                batch = self.stream.batch_at(i)
                t0 = time.time()
                state, metrics = self.step_fn(state, **batch)
                jax.block_until_ready(metrics)
                dt = time.time() - t0
                self._track(i, dt, metrics)
                i += 1
                # NEW progress (not a replayed step) refills the retry budget:
                # the bounded-retries contract is about *consecutive
                # unrecovered* failures, so a long run with sparse transient
                # faults never trips it (stats.failures still counts all),
                # while a step that faults on every attempt still exhausts the
                # budget -- its replays never pass the old high-water mark.
                if i > high_water:
                    high_water = i
                    consecutive_failures = 0
                if i % self.cfg.ckpt_every == 0 or i == n_steps:
                    save_checkpoint(self.cfg.ckpt_dir, i, state)
            except (InjectedFault, RuntimeError) as e:
                consecutive_failures += 1
                self.stats.failures += 1
                if consecutive_failures > self.cfg.max_failures:
                    raise RuntimeError(
                        f"exceeded {self.cfg.max_failures} consecutive "
                        f"failures; last: {e}"
                    ) from e
                # restore from the newest complete checkpoint and replay --
                # but only a checkpoint *this run* could have produced
                # (within [start_step, high_water]): a stale checkpoint from
                # an earlier run on the same dir would jump a fresh
                # resume=False run to foreign state/progress.  Otherwise
                # replay from the entry state (state and index rewind
                # together).
                step = latest_step(self.cfg.ckpt_dir)
                if step is not None and start_step <= step <= high_water:
                    state, i = self._maybe_restore(state)[0], step
                else:
                    state, i = entry_state, start_step
        return state, self.stats

    def _track(self, step: int, dt: float, metrics):
        # Steps replayed after a checkpoint restore re-run below the
        # watermark: they already fed steps/losses/EMA (and the compute
        # observer) once, so counting them again would double-feed every
        # stat for every replayed step.
        if step < self._tracked_upto:
            return
        self._tracked_upto = step + 1
        s = self.stats
        if s.ema_step_s == 0.0:
            s.ema_step_s = dt
        elif dt > self.cfg.straggler_factor * s.ema_step_s:
            s.stragglers += 1
        s.ema_step_s = (1 - self.cfg.ema_alpha) * s.ema_step_s + self.cfg.ema_alpha * dt
        s.steps += 1
        loss = metrics.get("total", metrics.get("loss", metrics.get("ce")))
        if loss is not None:
            s.losses.append(float(loss))
        if self.compute_observer is not None and self.step_flops:
            self.compute_observer(self.es_name, self.step_flops, dt)
