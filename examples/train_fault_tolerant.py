"""Fault-tolerant training demo: train a reduced ViT for a few hundred steps
on synthetic data, inject two node failures, and show checkpoint/restart
producing the same final parameters as an uninterrupted run.

    PYTHONPATH=src python examples/train_fault_tolerant.py --steps 120
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.runtime.fault import FaultConfig, InjectedFault
from repro.runtime.train import make_trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--arch", default="vit-l16")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as d_ref, tempfile.TemporaryDirectory() as d_ft:
        print(f"== reference run ({args.steps} steps, no faults) ==")
        t_ref, s_ref = make_trainer(
            args.arch, "cls_224", fault_cfg=FaultConfig(ckpt_dir=d_ref, ckpt_every=20)
        )
        s_ref, st_ref = t_ref.run(s_ref, args.steps, resume=False)
        print(f"loss: {st_ref.losses[0]:.4f} -> {st_ref.losses[-1]:.4f} "
              f"(ema step {st_ref.ema_step_s*1e3:.0f}ms)")

        print("\n== chaos run: kill the job at steps 37 and 83 ==")
        boom = {"left": [37, 83]}

        def chaos(i):
            if boom["left"] and i == boom["left"][0]:
                boom["left"].pop(0)
                print(f"  !! injected node failure at step {i}")
                raise InjectedFault(f"node failure at step {i}")

        t_ft, s_ft = make_trainer(
            args.arch, "cls_224",
            fault_cfg=FaultConfig(ckpt_dir=d_ft, ckpt_every=20),
            fault_hook=chaos,
        )
        s_ft, st = t_ft.run(s_ft, args.steps, resume=False)
        print(f"failures={st.failures} restores={st.restores} "
              f"loss: {st.losses[0]:.4f} -> {st.losses[-1]:.4f}")

        ok = all(
            np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                        rtol=1e-5, atol=1e-6)
            for a, b in zip(
                jax.tree_util.tree_leaves(s_ref[0]), jax.tree_util.tree_leaves(s_ft[0])
            )
        )
        print(f"\nfinal params identical to uninterrupted run: {ok}")
        assert ok


if __name__ == "__main__":
    main()
