"""Network geometry descriptions used by the partitioner / scheduler / simulator.

These are *analytical* descriptions (layer geometry + FLOP/byte accounting), kept
separate from the runnable JAX models in ``repro.models`` so the paper's
scheduling mathematics can be applied to any conv net -- including the assigned
vision architectures -- without instantiating parameters.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .rf import LayerGeom, attn, conv, pool, out_size

__all__ = ["ConvNetGeom", "vgg16_geom", "vit_l16_geom", "DTYPE_BYTES"]

DTYPE_BYTES = 4  # paper assumes float32 tensors (eq. 10 note)


@dataclass(frozen=True)
class ConvNetGeom:
    """A conv backbone: sliding-window layers + a fused 'head' FLOP count.

    The head (VGG's fully-connected layers / a classifier) runs after the final
    merge on the host (paper §IV.A: "all the sub-outputs ... merged as the input
    for FLs"), so only its FLOP count matters to the schedule.
    """

    name: str
    in_rows: int  # input height == width (square inputs, paper §II)
    in_channels: int
    layers: tuple[LayerGeom, ...]
    head_flops: float = 0.0

    def sizes(self) -> list[int]:
        """Spatial size before each layer; sizes()[i] is the input rows of layer i,
        and sizes()[-1] the final feature rows.  Memoised per *instance* (the
        planner's inner loops call this thousands of times, and hashing the
        geometry would cost more than the loop); the returned list is a fresh
        copy, so callers may mutate it freely."""
        cached = self.__dict__.get("_sizes")
        if cached is None:
            out = [self.in_rows]
            for g in self.layers:
                out.append(out_size(out[-1], g.k, g.s, g.p))
            cached = tuple(out)
            object.__setattr__(self, "_sizes", cached)
        return list(cached)

    def layer_flops(self, i: int, rows: int | None = None) -> float:
        """FLOPs of layer i restricted to ``rows`` output rows (None = all)."""
        g = self.layers[i]
        o = self.sizes()[i + 1]
        r = o if rows is None else rows
        return g.flops_per_out_row(out_width=o) * r

    def total_flops(self) -> float:
        return sum(self.layer_flops(i) for i in range(len(self.layers))) + self.head_flops

    def feature_bytes(self, i: int, rows: int | None = None) -> float:
        """Bytes of the *output* tensor of layer i restricted to ``rows`` rows."""
        g = self.layers[i]
        o = self.sizes()[i + 1]
        r = o if rows is None else rows
        return DTYPE_BYTES * r * o * g.c_out


def vgg16_geom(in_rows: int = 224) -> ConvNetGeom:
    """VGG-16 (Simonyan & Zisserman, ICLR'15) -- the paper's evaluation model.

    13 conv layers (3x3, s1, p1) in 5 blocks separated by 2x2/s2 max-pools,
    followed by FC 25088->4096->4096->1000 (the head).
    """
    cfg = [(2, 64), (2, 128), (3, 256), (3, 512), (3, 512)]
    layers: list[LayerGeom] = []
    c_in = 3
    for b, (reps, c_out) in enumerate(cfg, start=1):
        for r in range(1, reps + 1):
            layers.append(conv(f"conv{b}_{r}", c_in, c_out, k=3, s=1, p=1))
            c_in = c_out
        layers.append(pool(f"pool{b}", c_in))
    fc = [(512 * 7 * 7, 4096), (4096, 4096), (4096, 1000)]
    head = sum(2.0 * a * b for a, b in fc)
    return ConvNetGeom(
        name="vgg16", in_rows=in_rows, in_channels=3, layers=tuple(layers), head_flops=head
    )


def vit_l16_geom(
    in_rows: int = 224,
    patch: int = 16,
    n_blocks: int = 24,
    d: int = 1024,
    heads: int = 16,
    d_ff: int = 4096,
    num_classes: int = 1000,
    name: str = "vit_l16",
) -> ConvNetGeom:
    """ViT-L/16 as a spatial geometry: a patch-embedding conv (k=s=patch)
    followed by ``n_blocks`` of [attn, 1x1 out-projection, 1x1 MLP-up, 1x1
    MLP-down] over the H/patch x W/patch token grid, plus a classifier head.

    Residual adds and layernorms are FLOP-negligible next to the matmuls and
    byte-identical to the 1x1 outputs, so the analytical geometry omits them;
    the runnable counterpart in ``repro.models.vit_spatial`` matches this
    layer-for-layer so ``run_plan`` losslessness can be checked shape-exactly.
    The attention layers mean this net has *no* valid row/halo partitioning --
    it exists to exercise the head/sequence scheme.
    """
    layers: list[LayerGeom] = [conv("patch", 3, d, k=patch, s=patch, p=0)]
    for b in range(n_blocks):
        layers.append(attn(f"attn{b}", d, heads))
        layers.append(conv(f"proj{b}", d, d, k=1, s=1, p=0))
        layers.append(conv(f"mlp{b}_up", d, d_ff, k=1, s=1, p=0))
        layers.append(conv(f"mlp{b}_dn", d_ff, d, k=1, s=1, p=0))
    head = 2.0 * d * num_classes
    return ConvNetGeom(
        name=name, in_rows=in_rows, in_channels=3, layers=tuple(layers), head_flops=head
    )
