from .sharding import (
    input_shardings,
    param_shardings,
    shard_rules,
    spatial_shardings,
    state_shardings,
    weighted_spatial_inputs,
)
