"""Diagnose a cell's HLO: top individual ops by (trip-multiplied) bytes.

    PYTHONPATH=src python benchmarks/diagnose.py qwen3-4b train_4k pod16x16 [variant]
"""
import re
import sys
from pathlib import Path

import zstandard as zstd

sys.path.insert(0, "src")

from repro.launch.hlo_cost import (
    _FUSED_ELEMENTWISE,
    _SKIP_BYTES,
    _operands,
    _parse,
    _shape_elems_bytes,
)

RESULTS = Path(__file__).parent / "dryrun_results"


def diagnose(arch, cell, mesh="pod16x16", variant=None, top=25):
    suffix = f"__{variant}" if variant and variant != "base" else ""
    f = RESULTS / "hlo" / f"{arch}__{cell}__{mesh}{suffix}.hlo.zst"
    text = zstd.ZstdDecompressor().decompress(f.read_bytes(), max_output_size=2**31).decode()
    comps, entry, types = _parse(text)

    # computation -> multiplier (product of enclosing while trip counts)
    mult = {entry: 1.0}
    fused = set()
    changed = True
    order = list(comps)
    while changed:
        changed = False
        for name, comp in comps.items():
            if name not in mult:
                continue
            m0 = mult[name]
            for op in comp.ops:
                if op.opcode == "while":
                    t = re.search(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)', op.rest)
                    trip = int(t.group(1)) if t else 1
                    for key, mm in (("body", trip), ("condition", trip + 1)):
                        r = re.search(key + r"=(%[\w.\-]+)", op.rest)
                        if r and mult.get(r.group(1)) != m0 * mm:
                            mult[r.group(1)] = m0 * mm
                            changed = True
                elif op.opcode == "fusion":
                    r = re.search(r"calls=(%[\w.\-]+)", op.rest)
                    if r:
                        fused.add(r.group(1))
                elif op.opcode in ("call", "conditional"):
                    for r in re.finditer(r"(?:to_apply|calls)=(%[\w.\-]+)", op.rest):
                        if mult.get(r.group(1)) != m0:
                            mult[r.group(1)] = m0
                            changed = True

    rows = []
    for name, comp in comps.items():
        m0 = mult.get(name)
        if m0 is None or name in fused:
            continue
        for op in comp.ops:
            if op.opcode in _SKIP_BYTES or op.opcode in _FUSED_ELEMENTWISE:
                continue
            if op.opcode.endswith("-done"):
                continue
            _, res_b = _shape_elems_bytes(op.result_type)
            if op.opcode in ("dynamic-slice", "gather"):
                nb = 2 * res_b
            elif op.opcode == "dynamic-update-slice":
                ops_ = _operands(op.rest)
                nb = 2 * (_shape_elems_bytes(types.get(ops_[1], ""))[1] if len(ops_) > 1 else res_b)
            else:
                nb = res_b + sum(_shape_elems_bytes(types.get(o, ""))[1] for o in _operands(op.rest))
            meta = re.search(r'op_name="([^"]*)"', op.rest)
            rows.append((nb * m0, m0, op.opcode, op.result_type[:60],
                         (meta.group(1) if meta else "")[:90]))
    rows.sort(key=lambda r: -r[0])
    print(f"== top {top} ops by bytes: {arch}/{cell}/{mesh}{suffix} ==")
    for nb, m0, opc, ty, mn in rows[:top]:
        print(f"{nb/1e9:12.1f} GB x{m0:6.0f} {opc:22s} {ty:60s} {mn}")
    total = sum(r[0] for r in rows)
    print(f"total bytes: {total/1e9:.1f} GB")


if __name__ == "__main__":
    diagnose(*sys.argv[1:])
