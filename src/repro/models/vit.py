"""ViT (Dosovitskiy et al., arXiv:2010.11929) -- vit-l16 and friends."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

from .common import Params, conv_params, dense_params, keygen, norm_params, stack_layers, trunc_normal
from .layers import conv2d, dense, gelu, layernorm, softmax_xent

__all__ = ["ViTConfig", "init", "apply", "vit_block_init", "vit_block_apply"]


@dataclass(frozen=True)
class ViTConfig:
    name: str = "vit-l16"
    img_res: int = 224
    patch: int = 16
    n_layers: int = 24
    d_model: int = 1024
    n_heads: int = 16
    d_ff: int = 4096
    num_classes: int = 1000
    in_channels: int = 3
    remat: bool = True

    @property
    def n_tokens(self) -> int:
        return (self.img_res // self.patch) ** 2 + 1  # + cls token


def vit_block_init(key, d_model, n_heads, d_ff, dtype=jnp.float32) -> Params:
    ks = keygen(key)
    return {
        "ln1": norm_params(d_model, dtype=dtype),
        "wqkv": dense_params(next(ks), d_model, 3 * d_model, dtype=dtype),
        "wo": dense_params(next(ks), d_model, d_model, dtype=dtype),
        "ln2": norm_params(d_model, dtype=dtype),
        "fc1": dense_params(next(ks), d_model, d_ff, dtype=dtype),
        "fc2": dense_params(next(ks), d_ff, d_model, dtype=dtype),
    }


def vit_block_apply(p: Params, x: jax.Array, n_heads: int) -> jax.Array:
    """Pre-LN transformer encoder block; x [B, N, D]."""
    b, n, d = x.shape
    h = layernorm(x, p["ln1"])
    qkv = dense(h, p["wqkv"]).reshape(b, n, 3, n_heads, d // n_heads)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    logits = jnp.einsum("bnhd,bmhd->bhnm", q, k) / jnp.sqrt(d / n_heads)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(x.dtype)
    a = jnp.einsum("bhnm,bmhd->bnhd", probs, v).reshape(b, n, d)
    x = x + dense(a, p["wo"])
    h = layernorm(x, p["ln2"])
    return x + dense(gelu(dense(h, p["fc1"])), p["fc2"])


def init(key, cfg: ViTConfig, dtype=jnp.float32) -> Params:
    ks = keygen(key)
    return {
        "patch_embed": conv_params(next(ks), cfg.patch, cfg.in_channels, cfg.d_model, dtype=dtype),
        "cls": trunc_normal(next(ks), (1, 1, cfg.d_model), dtype=dtype),
        "pos": trunc_normal(next(ks), (1, cfg.n_tokens, cfg.d_model), dtype=dtype),
        "blocks": stack_layers(
            lambda k: vit_block_init(k, cfg.d_model, cfg.n_heads, cfg.d_ff, dtype),
            next(ks),
            cfg.n_layers,
        ),
        "ln": norm_params(cfg.d_model, dtype=dtype),
        "head": dense_params(next(ks), cfg.d_model, cfg.num_classes, dtype=dtype),
    }


def apply(params: Params, cfg: ViTConfig, x: jax.Array) -> jax.Array:
    """x [B, H, W, C] -> logits [B, classes]."""
    b = x.shape[0]
    x = conv2d(x, params["patch_embed"], stride=cfg.patch, padding="VALID")
    x = x.reshape(b, -1, cfg.d_model)
    x = jnp.concatenate([jnp.broadcast_to(params["cls"], (b, 1, cfg.d_model)), x], axis=1)
    x = x + params["pos"]

    def body(h, p_l):
        return vit_block_apply(p_l, h, cfg.n_heads), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = lax.scan(body, x, params["blocks"])
    x = layernorm(x, params["ln"])
    return dense(x[:, 0], params["head"])


def loss_fn(params, cfg: ViTConfig, images, labels):
    logits = apply(params, cfg, images)
    return softmax_xent(logits, labels), {"logits": logits}
