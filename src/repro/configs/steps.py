"""Step builders: (arch, cell) -> jit-able function + abstract inputs.

The same builder feeds three consumers:
* smoke tests  -- reduced configs, real arrays, one step on CPU,
* the dry-run  -- full configs, ShapeDtypeStructs, lower+compile on the
  production mesh (no allocation),
* the drivers  -- examples/ and launch/train.py / launch/serve.py.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models import dit, efficientnet, swin, transformer_lm as lm, unet, vit
from ..optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from .base import Arch, Cell

__all__ = ["StepBundle", "build", "abstract_params", "abstract_state"]


@dataclass
class StepBundle:
    """Everything needed to jit/lower one (arch, cell)."""

    fn: Callable  # fn(state_or_params, *inputs)
    state: Any  # abstract pytree (params or (params, opt, step))
    inputs: dict[str, Any]  # name -> ShapeDtypeStruct (ordered)
    donate_state: bool  # whether arg 0 should be donated
    kind: str

    @property
    def input_list(self):
        return list(self.inputs.values())


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def abstract_params(arch: Arch, cfg, dtype):
    init = partial(arch.module.init, cfg=cfg, dtype=dtype)
    return jax.eval_shape(init, jax.random.PRNGKey(0))


def abstract_state(arch: Arch, cfg, dtype, opt_cfg: AdamWConfig):
    params = abstract_params(arch, cfg, dtype)
    opt = jax.eval_shape(partial(adamw_init, cfg=opt_cfg), params)
    return (params, opt, _sds((), jnp.int32))


def _opt_cfg_for(arch: Arch) -> AdamWConfig:
    # bf16 moments for the very large configs (fits 512 x 16 GB; DESIGN.md).
    if arch.name.startswith("deepseek"):
        return AdamWConfig(moment_dtype=jnp.bfloat16)
    return AdamWConfig()


def _adapt_vision_cfg(arch: Arch, cfg, img_res: int):
    cfg = dataclasses.replace(cfg, img_res=img_res)
    if arch.family == "vision" and hasattr(cfg, "window") and img_res == 384:
        # Swin finetunes at 384 with window 12 (arXiv:2103.14030 §4.1)
        cfg = dataclasses.replace(cfg, window=12)
    return cfg


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


def _lm_train(arch: Arch, cfg, cell: Cell, dtype) -> StepBundle:
    opt_cfg = _opt_cfg_for(arch)

    def step(state, tokens, labels):
        params, opt, n = state
        (loss, metrics), grads = jax.value_and_grad(lm.loss_fn, has_aux=True)(
            params, cfg, tokens, labels
        )
        params, opt = adamw_update(grads, opt, params, opt_cfg, warmup_cosine(n))
        return (params, opt, n + 1), metrics

    b, s = cell.meta["global_batch"], cell.meta["seq_len"]
    return StepBundle(
        fn=step,
        state=abstract_state(arch, cfg, dtype, opt_cfg),
        inputs={"tokens": _sds((b, s), jnp.int32), "labels": _sds((b, s), jnp.int32)},
        donate_state=True,
        kind="train",
    )


def _lm_prefill(arch: Arch, cfg, cell: Cell, dtype) -> StepBundle:
    def step(params, tokens):
        logits, _ = lm.forward(params, cfg, tokens)
        return logits

    b, s = cell.meta["global_batch"], cell.meta["seq_len"]
    return StepBundle(
        fn=step,
        state=abstract_params(arch, cfg, dtype),
        inputs={"tokens": _sds((b, s), jnp.int32)},
        donate_state=False,
        kind="prefill",
    )


def _lm_decode(arch: Arch, cfg, cell: Cell, dtype) -> StepBundle:
    b, s = cell.meta["global_batch"], cell.meta["seq_len"]

    def step(params, cache, tokens, index):
        return lm.decode_step(params, cfg, cache, tokens, index)

    cache = jax.eval_shape(partial(lm.init_cache, cfg, b, s, dtype=dtype))
    return StepBundle(
        fn=step,
        state=abstract_params(arch, cfg, dtype),
        inputs={
            "cache": cache,
            "tokens": _sds((b, 1), jnp.int32),
            "index": _sds((), jnp.int32),
        },
        donate_state=False,
        kind="decode",
    )


# ---------------------------------------------------------------------------
# vision family
# ---------------------------------------------------------------------------


def _vision_train(arch: Arch, cfg, cell: Cell, dtype) -> StepBundle:
    cfg = _adapt_vision_cfg(arch, cfg, cell.meta["img_res"])
    opt_cfg = _opt_cfg_for(arch)

    def step(state, images, labels):
        params, opt, n = state
        (loss, aux), grads = jax.value_and_grad(arch.module.loss_fn, has_aux=True)(
            params, cfg, images, labels
        )
        params, opt = adamw_update(grads, opt, params, opt_cfg, warmup_cosine(n))
        return (params, opt, n + 1), {"loss": loss}

    b, r = cell.meta["batch"], cell.meta["img_res"]
    return StepBundle(
        fn=step,
        state=abstract_state(arch, cfg, dtype, opt_cfg),
        inputs={
            "images": _sds((b, r, r, 3), dtype),
            "labels": _sds((b,), jnp.int32),
        },
        donate_state=True,
        kind="train",
    )


def _vision_serve(arch: Arch, cfg, cell: Cell, dtype) -> StepBundle:
    cfg = _adapt_vision_cfg(arch, cfg, cell.meta["img_res"])

    def step(params, images):
        return arch.module.apply(params, cfg, images)

    b, r = cell.meta["batch"], cell.meta["img_res"]
    return StepBundle(
        fn=step,
        state=abstract_params(arch, cfg, dtype),
        inputs={"images": _sds((b, r, r, 3), dtype)},
        donate_state=False,
        kind="serve",
    )


# ---------------------------------------------------------------------------
# diffusion family
# ---------------------------------------------------------------------------


def _diff_cfg(arch: Arch, cfg, img_res: int):
    return dataclasses.replace(cfg, img_res=img_res)


def _diffusion_cond_specs(arch: Arch, cfg, b, dtype):
    if arch.module is dit:
        return {"cond": _sds((b,), jnp.int32)}
    return {"cond": _sds((b, cfg.ctx_len, cfg.ctx_dim), dtype)}


def _diffusion_apply(arch: Arch, cfg, params, lat, t, cond):
    if arch.module is dit:
        return dit.apply(params, cfg, lat, t, cond)[..., : cfg.latent_ch]
    return unet.apply(params, cfg, lat, t, cond)


def _diffusion_train(arch: Arch, cfg, cell: Cell, dtype) -> StepBundle:
    cfg = _diff_cfg(arch, cfg, cell.meta["img_res"])
    opt_cfg = _opt_cfg_for(arch)
    n_steps = cell.meta.get("steps", 1000)

    def loss_fn(params, latents, t, cond, noise):
        # cosine-ish alpha schedule; eps-prediction MSE (DDPM objective)
        a = jnp.cos(0.5 * jnp.pi * (t.astype(jnp.float32) / n_steps)) ** 2
        a = a[:, None, None, None].astype(latents.dtype)
        x_t = jnp.sqrt(a) * latents + jnp.sqrt(1.0 - a) * noise
        pred = _diffusion_apply(arch, cfg, params, x_t, t, cond)
        return jnp.mean(jnp.square(pred.astype(jnp.float32) - noise.astype(jnp.float32)))

    def step(state, latents, t, cond, noise):
        params, opt, n = state
        loss, grads = jax.value_and_grad(loss_fn)(params, latents, t, cond, noise)
        params, opt = adamw_update(grads, opt, params, opt_cfg, warmup_cosine(n))
        return (params, opt, n + 1), {"loss": loss}

    b = cell.meta["batch"]
    lr = cfg.latent_res
    lat = _sds((b, lr, lr, cfg.latent_ch), dtype)
    cond = _diffusion_cond_specs(arch, cfg, b, dtype)
    inputs = {"latents": lat, "t": _sds((b,), jnp.int32), **cond, "noise": lat}
    return StepBundle(
        fn=step,
        state=abstract_state(arch, cfg, dtype, opt_cfg),
        inputs=inputs,
        donate_state=True,
        kind="train",
    )


def _diffusion_gen(arch: Arch, cfg, cell: Cell, dtype) -> StepBundle:
    from ..parallel.variants import get_variant

    cfg = _diff_cfg(arch, cfg, cell.meta["img_res"])
    if get_variant().diffusion_spatial2d and hasattr(cfg, "attn_f32"):
        # serving variant: SD-style low-precision softmax (§Perf iteration 3)
        cfg = dataclasses.replace(cfg, attn_f32=False)
    n_steps = cell.meta["steps"]
    n_train = 1000

    def sample(params, latents, cond):
        """DDIM sampler: ``n_steps`` scanned forwards of the backbone."""
        ts = jnp.linspace(n_train - 1, 1, n_steps).astype(jnp.int32)

        def alpha(t):
            return jnp.cos(0.5 * jnp.pi * (t.astype(jnp.float32) / n_train)) ** 2

        def body(lat, tpair):
            t, t_next = tpair
            tb = jnp.full((lat.shape[0],), t, jnp.int32)
            eps = _diffusion_apply(arch, cfg, params, lat, tb, cond)
            a, an = alpha(t), alpha(t_next)
            x0 = (lat - jnp.sqrt(1 - a) * eps) / jnp.sqrt(a)
            lat = jnp.sqrt(an) * x0 + jnp.sqrt(1 - an) * eps
            return lat.astype(latents.dtype), None

        pairs = (ts, jnp.concatenate([ts[1:], jnp.zeros((1,), jnp.int32)]))
        lat, _ = jax.lax.scan(body, latents, pairs)
        return lat

    b = cell.meta["batch"]
    lr = cfg.latent_res
    cond = _diffusion_cond_specs(arch, cfg, b, dtype)
    inputs = {"latents": _sds((b, lr, lr, cfg.latent_ch), dtype), **cond}
    return StepBundle(
        fn=sample,
        state=abstract_params(arch, cfg, dtype),
        inputs=inputs,
        donate_state=False,
        kind="gen",
    )


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

_BUILDERS = {
    ("lm", "train"): _lm_train,
    ("lm", "prefill"): _lm_prefill,
    ("lm", "decode"): _lm_decode,
    ("vision", "train"): _vision_train,
    ("vision", "serve"): _vision_serve,
    ("diffusion", "train"): _diffusion_train,
    ("diffusion", "gen"): _diffusion_gen,
}


def build(arch: Arch, cell_name: str, *, smoke: bool = False, dtype=None) -> StepBundle:
    cell = arch.cells[cell_name]
    if cell.skip:
        raise ValueError(f"{arch.name}/{cell_name} is skipped: {cell.skip}")
    cfg = arch.smoke_cfg if smoke else arch.cfg
    if smoke:
        cell = _shrink(cell)
    if dtype is None:
        dtype = jnp.float32 if smoke else jnp.bfloat16
    return _BUILDERS[(arch.family, cell.kind)](arch, cfg, cell, dtype)


def realize(arch: Arch, bundle: StepBundle, key, *, smoke: bool = True):
    """Materialise real (state, inputs) for a bundle -- used by smoke tests and
    the CPU example drivers.  Random inputs; zeros caches."""
    cfg = arch.smoke_cfg if smoke else arch.cfg
    dtype = jax.tree_util.tree_leaves(bundle.state)[0].dtype
    k_init, k_in = jax.random.split(jax.random.PRNGKey(0) if key is None else key)
    params = arch.module.init(k_init, cfg, dtype=dtype)
    if bundle.kind == "train":
        opt = adamw_init(params, _opt_cfg_for(arch))
        state = (params, opt, jnp.zeros((), jnp.int32))
    else:
        state = params
    inputs = {}
    for name, spec in bundle.inputs.items():
        k_in, k = jax.random.split(k_in)
        inputs[name] = _random_like(spec, k)
    return state, inputs


def _random_like(spec, key):
    if isinstance(spec, dict) or not hasattr(spec, "dtype"):
        # pytree (e.g. a KV cache): zeros
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), spec
        )
    if jnp.issubdtype(spec.dtype, jnp.integer):
        if spec.shape == ():
            return jnp.zeros((), spec.dtype)
        # stay below every smoke config's num_classes / vocab
        return jax.random.randint(key, spec.shape, 0, 8).astype(spec.dtype)
    return jax.random.normal(key, spec.shape, jnp.float32).astype(spec.dtype)


def _shrink(cell: Cell) -> Cell:
    m = dict(cell.meta)
    if "seq_len" in m:
        m["seq_len"] = 128 if cell.kind == "decode" else 64
    for k, v in (("global_batch", 2), ("batch", 2), ("steps", 2)):
        if k in m:
            m[k] = min(m[k], v)
    if "img_res" in m:
        m["img_res"] = 64
    return Cell(cell.name, cell.kind, m, None)
