"""Pallas kernel tests: interpret=True (CPU) vs. pure-jnp oracles, with
shape/dtype sweeps per kernel as the deliverable requires."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image without hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.kernels.attention import attention_ref, flash_attention, gqa_flash
from repro.kernels.conv2d import conv2d_pallas, conv2d_ref
from repro.kernels.halo_conv import halo_conv2d, halo_conv2d_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------

CONV_CASES = [
    # (N, H, W, Cin, Cout, k, pad)
    (1, 16, 16, 8, 16, 3, 1),
    (2, 32, 24, 16, 32, 3, 1),
    (1, 8, 8, 4, 8, 1, 0),
    (1, 20, 20, 8, 16, 5, 2),
    (2, 14, 14, 32, 64, 3, 1),  # VGG-16 deep-layer-like
    (1, 17, 13, 3, 8, 3, 1),  # odd sizes
]


@pytest.mark.parametrize("case", CONV_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv2d_kernel_matches_ref(case, dtype):
    n, h, w, cin, cout, k, pad = case
    kx, kw, kb = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(kx, (n, h, w, cin), jnp.float32).astype(dtype)
    wts = (0.1 * jax.random.normal(kw, (k, k, cin, cout), jnp.float32)).astype(dtype)
    b = jax.random.normal(kb, (cout,), jnp.float32).astype(dtype)
    got = conv2d_pallas(x, wts, b, padding=pad, interpret=True)
    want = conv2d_ref(x, wts, b, padding=pad)
    assert got.shape == want.shape
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_conv2d_matches_lax_conv():
    """Cross-check the oracle itself against lax.conv_general_dilated."""
    from jax import lax

    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 8))
    w = jax.random.normal(jax.random.PRNGKey(2), (3, 3, 8, 16)) * 0.1
    want = lax.conv_general_dilated(
        x, w, (1, 1), [(1, 1), (1, 1)], dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    got = conv2d_ref(x, w, padding=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@given(
    h=st.integers(6, 24),
    w=st.integers(6, 24),
    cin=st.sampled_from([3, 4, 8]),
    cout=st.sampled_from([8, 16]),
    k=st.sampled_from([1, 3, 5]),
)
@settings(max_examples=25, deadline=None)
def test_conv2d_kernel_property(h, w, cin, cout, k):
    pad = k // 2
    x = jax.random.normal(jax.random.PRNGKey(h * w), (1, h, w, cin))
    wts = 0.1 * jax.random.normal(jax.random.PRNGKey(k), (k, k, cin, cout))
    got = conv2d_pallas(x, wts, padding=pad, interpret=True)
    want = conv2d_ref(x, wts, padding=pad)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (B, H, T, S, D, causal)
    (1, 2, 128, 128, 32, True),
    (2, 4, 256, 256, 64, True),
    (1, 2, 128, 128, 32, False),
    (1, 1, 64, 64, 16, True),
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    b, h, t, s, d, causal = case
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (b, h, t, d), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (b, h, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (b, h, s, d), jnp.float32).astype(dtype)
    got = flash_attention(q, k, v, causal=causal, q_block=64, kv_block=64, interpret=True)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_gqa_flash_matches_model_sdpa():
    """GQA wrapper vs. the model's grouped _sdpa (the production oracle)."""
    from repro.models.attention import _sdpa

    b, t, h, hkv, d = 2, 128, 8, 2, 32
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(kq, (b, t, h, d))
    k = jax.random.normal(kk, (b, t, hkv, d))
    v = jax.random.normal(kv, (b, t, hkv, d))
    mask = jnp.tril(jnp.ones((t, t), bool))[None, None, None]
    want = _sdpa(q, k, v, mask, d**-0.5)
    got = gqa_flash(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("t", [64, 192, 256])
def test_flash_attention_block_sweep(t):
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, t, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, t, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, t, 32))
    want = attention_ref(q, k, v, causal=True)
    for qb, kb in ((32, 64), (64, 32), (64, 64)):
        if t % qb or t % kb:
            continue
        got = flash_attention(q, k, v, causal=True, q_block=qb, kv_block=kb, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# halo conv
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k,pad", [(3, 1), (5, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_halo_conv_matches_ref(k, pad, dtype):
    b, hs, w, cin, cout = 2, 16, 12, 8, 16
    lo, hi = pad, k - 1 - pad
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(keys[0], (b, hs, w, cin), jnp.float32).astype(dtype)
    top = jax.random.normal(keys[1], (b, lo, w, cin), jnp.float32).astype(dtype)
    bot = jax.random.normal(keys[2], (b, hi, w, cin), jnp.float32).astype(dtype)
    wts = (0.1 * jax.random.normal(keys[3], (k, k, cin, cout), jnp.float32)).astype(dtype)
    got = halo_conv2d(x, top, bot, wts, padding=pad, interpret=True)
    want = halo_conv2d_ref(x, top, bot, wts, padding=pad)
    # the reference computes the full extended conv; our op returns the shard rows
    want = want[:, : hs]
    assert got.shape == want.shape, (got.shape, want.shape)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


STRIDED_CASES = [
    # (N, H, W, Cin, Cout, k, stride, pad)
    (1, 16, 16, 8, 16, 3, 2, 1),
    (1, 64, 64, 3, 16, 7, 2, 3),  # ResNet/EfficientNet stem
    (2, 32, 32, 4, 8, 2, 2, 0),   # pool-like conv
    (1, 20, 20, 8, 16, 5, 2, 2),
    (1, 17, 13, 3, 8, 3, 2, 1),   # odd sizes, strided
]


@pytest.mark.parametrize("case", STRIDED_CASES)
def test_conv2d_kernel_strided(case):
    n, h, w, cin, cout, k, s, pad = case
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (n, h, w, cin))
    wts = 0.1 * jax.random.normal(kw, (k, k, cin, cout))
    got = conv2d_pallas(x, wts, stride=s, padding=pad, interpret=True)
    want = conv2d_ref(x, wts, stride=s, padding=pad)
    assert got.shape == want.shape, (got.shape, want.shape)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("k,stride", [(3, 1), (7, 1), (3, 2)])
def test_conv2d_kernel_depthwise(k, stride):
    """Depthwise path (groups == cin == cout): VPU mul-add, no MXU matmul."""
    c, pad = 8, k // 2
    kx, kw = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (1, 24, 20, c))
    wts = 0.1 * jax.random.normal(kw, (k, k, 1, c))
    got = conv2d_pallas(x, wts, stride=stride, padding=pad, groups=c, interpret=True)
    want = conv2d_ref(x, wts, stride=stride, padding=pad, groups=c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_conv2d_kernel_rejects_grouped_non_depthwise():
    x = jnp.zeros((1, 8, 8, 8))
    wts = jnp.zeros((3, 3, 4, 8))  # groups=2: neither dense nor depthwise
    with pytest.raises(ValueError, match="depthwise"):
        conv2d_pallas(x, wts, padding=1, groups=2, interpret=True)


@pytest.mark.parametrize("k,stride,pad", [(3, 1, 1), (5, 1, 2), (3, 2, 1), (5, 2, 3), (7, 2, 3)])
def test_halo_conv_stride_sweep(k, stride, pad):
    """Acceptance sweep: fused kernel vs concat-then-conv oracle for k in
    {3,5,7}, stride in {1,2} with exact halos lo + hi == k - s."""
    b, hs, w, cin, cout = 1, 16, 11, 4, 8
    lo, hi = pad, k - pad - stride
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    x = jax.random.normal(keys[0], (b, hs, w, cin))
    top = jax.random.normal(keys[1], (b, lo, w, cin)) if lo else None
    bot = jax.random.normal(keys[2], (b, hi, w, cin)) if hi else None
    wts = 0.1 * jax.random.normal(keys[3], (k, k, cin, cout))
    got = halo_conv2d(x, top, bot, wts, stride=stride, padding=pad, interpret=True)
    want = halo_conv2d_ref(x, top, bot, wts, stride=stride, padding=pad)
    assert got.shape == want.shape, (got.shape, want.shape)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("hs,tile_h", [(10, 4), (16, 6), (7, 3)])
def test_halo_conv_remainder_tiles(hs, tile_h):
    """Regression pin: hs % tile_h != 0 must NOT drop the remainder rows.

    The pre-fix tiling used ``nt = hs // th``, silently truncating the shard's
    output; the ceil-tiling path must produce every row, bit-close to the
    oracle."""
    assert hs % tile_h != 0  # the case under test
    b, w, cin, cout, k, pad = 1, 9, 4, 8, 3, 1
    keys = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(keys[0], (b, hs, w, cin))
    top = jax.random.normal(keys[1], (b, pad, w, cin))
    bot = jax.random.normal(keys[2], (b, k - 1 - pad, w, cin))
    wts = 0.1 * jax.random.normal(keys[3], (k, k, cin, cout))
    got = halo_conv2d(x, top, bot, wts, padding=pad, tile_h=tile_h, interpret=True)
    want = halo_conv2d_ref(x, top, bot, wts, padding=pad)
    assert got.shape[1] == hs, got.shape  # every output row present
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_halo_conv_rejects_inexact_halos():
    x = jnp.zeros((1, 8, 8, 4))
    wts = jnp.zeros((3, 3, 4, 8))
    with pytest.raises(ValueError, match="lo \\+ hi"):
        halo_conv2d(x, jnp.zeros((1, 1, 8, 4)), jnp.zeros((1, 2, 8, 4)), wts,
                    padding=1, interpret=True)


def test_halo_conv_equals_unsharded_conv():
    """Two half-shards with exchanged halos == one unsharded conv (HALP
    losslessness at kernel level)."""
    b, h, w, cin, cout = 1, 32, 16, 4, 8
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (b, h, w, cin))
    wts = 0.1 * jax.random.normal(kw, (3, 3, cin, cout))
    want = conv2d_ref(x, wts, padding=1)
    top_shard, bot_shard = x[:, : h // 2], x[:, h // 2 :]
    zeros = jnp.zeros((b, 1, w, cin))
    y_top = halo_conv2d(top_shard, zeros, bot_shard[:, :1], wts, padding=1, interpret=True)
    y_bot = halo_conv2d(bot_shard, top_shard[:, -1:], zeros, wts, padding=1, interpret=True)
    got = jnp.concatenate([y_top, y_bot], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
