"""shard_map-level wrapper: ppermute halos + the HALP-fused Pallas conv."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .halo_conv import halo_conv2d


def conv2d_spatial_pallas(
    x: jax.Array,  # [B, Hs, W, C] height shard
    weights: jax.Array,
    bias=None,
    *,
    padding: int = 1,
    axis_name: str = "sp",
    interpret: bool = False,
) -> jax.Array:
    """Drop-in for repro.spatial.halo.conv2d_spatial (k = weights k, s=1) with
    the Pallas kernel as the compute body."""
    k = weights.shape[0]
    lo, hi = padding, k - 1 - padding
    n = lax.psum(1, axis_name)
    idx = lax.axis_index(axis_name)
    top = bot = None
    if lo:
        top = lax.ppermute(x[:, -lo:], axis_name, [(i, (i + 1) % n) for i in range(n)])
        top = jnp.where(idx == 0, jnp.zeros_like(top), top)
    if hi:
        bot = lax.ppermute(x[:, :hi], axis_name, [(i, (i - 1) % n) for i in range(n)])
        bot = jnp.where(idx == n - 1, jnp.zeros_like(bot), bot)
    return halo_conv2d(
        x, top, bot, weights, bias, padding=padding, interpret=interpret
    )
