"""Service-reliability model under a time-variant offloading channel (paper §V.D).

The IoT device offloads a batch of ``n_tasks`` images (125 KB each) to the host
ES; the offloading time is Gaussian, T_off ~ N(mu, sigma^2) with
mu = batch_bits / rate.  The service deadline D corresponds to the target
system throughput (30 FPS with 4 tasks per batch -> D = 4/30 s = 133.3 ms), and

    reliability = P(T_off + T_inf <= D) = Phi((D - mu - T_inf) / sigma).

Reverse-engineering note (validated in benchmarks/table3_reliability.py): the
paper's Table III entries are exactly Phi(slack/sigma) with a 4 Mbit offload --
e.g. 0.815931 = Phi(0.90), 0.571420 = Phi(0.90/5), 0.992992 = Phi(34.4/14) --
which pins the paper's implied constants: T_inf(pre-trained, Xavier) such that
slack at 40 Mbps is 0.9 ms, and T_inf(HALP) matching Table II's 225 fps entry.
The paper's rate-fluctuation column is phi = rate - batch_bits/(mu + 3 sigma)
(3-sigma rule), which reproduces every phi in the table header.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

__all__ = [
    "phi",
    "probit",
    "OffloadChannel",
    "service_reliability",
    "rate_fluctuation",
    "required_slack",
]

IMAGE_BYTES = 125_000  # paper: "each input image of 125 KBytes"


def phi(z: float) -> float:
    """Standard normal CDF."""
    return 0.5 * (1.0 + math.erf(z / math.sqrt(2.0)))


@dataclass(frozen=True)
class OffloadChannel:
    rate_bps: float  # nominal IoT->host rate
    sigma_s: float  # std-dev of the offloading time
    n_tasks: int = 4

    @property
    def batch_bits(self) -> float:
        return 8.0 * IMAGE_BYTES * self.n_tasks

    @property
    def mu_s(self) -> float:
        return self.batch_bits / self.rate_bps


def service_reliability(ch: OffloadChannel, t_inf_s: float, deadline_s: float) -> float:
    """P(T_off + T_inf <= D) for Gaussian offloading time."""
    if ch.sigma_s <= 0:
        return 1.0 if ch.mu_s + t_inf_s <= deadline_s else 0.0
    return phi((deadline_s - ch.mu_s - t_inf_s) / ch.sigma_s)


def rate_fluctuation(ch: OffloadChannel) -> float:
    """phi (Mbps-style fluctuation) via the 3-sigma rule: the nominal rate minus
    the effective rate when the offload takes mu + 3 sigma."""
    return ch.rate_bps - ch.batch_bits / (ch.mu_s + 3.0 * ch.sigma_s)


def probit(p: float) -> float:
    """Inverse standard normal CDF (quantile), ``phi(probit(p)) == p``.

    Solved by bisection on :func:`phi` -- monotone, branch-free of special
    cases, and accurate to ~1e-12 over the targets admission control uses
    (0.9 .. 0.999999); the stdlib has ``erf`` but no ``erfinv``, and pulling
    in scipy for one quantile is not worth a dependency."""
    if not 0.0 < p < 1.0:
        raise ValueError(f"p must be in (0, 1), got {p}")
    lo, hi = -9.0, 9.0  # phi saturates to 0/1 in float64 well inside +-9
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if phi(mid) < p:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def required_slack(ch: OffloadChannel, t_inf_s: float, target: float) -> float:
    """The smallest deadline slack at which a batch still clears ``target``:
    the §V.D reliability integral *inverted* into an admission threshold.

    ``service_reliability(ch, t_inf, D) >= target``  iff
    ``D >= mu + t_inf + sigma * probit(target)`` (for ``sigma > 0``; a
    deterministic channel degenerates to ``mu + t_inf``).  Admission control
    over a request stream uses this form: per deadline class the threshold is
    one precomputed number per batch size, so admitting or shedding a request
    with remaining slack ``deadline - now`` is a single comparison instead of
    a reliability evaluation -- what makes §V.D's policy affordable at
    millions of requests (see ``repro.runtime.serve.serve_trace``)."""
    if not 0.0 < target < 1.0:
        raise ValueError(f"target must be in (0, 1), got {target}")
    if ch.sigma_s <= 0:
        return ch.mu_s + t_inf_s
    return ch.mu_s + t_inf_s + ch.sigma_s * probit(target)
