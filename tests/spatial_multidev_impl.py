"""Multi-device spatial-parallelism checks; run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (see test_spatial.py).
Exits non-zero on any mismatch."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from functools import partial
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.models import vgg
from repro.models.layers import conv2d, max_pool, relu
from repro.spatial import conv2d_spatial, max_pool_spatial
from repro.models.common import conv_params

assert len(jax.devices()) == 8, jax.devices()
mesh = Mesh(np.array(jax.devices()).reshape(8), ("sp",))


def check(name, got, want, tol=2e-5):
    got, want = np.asarray(got), np.asarray(want)
    assert got.shape == want.shape, (name, got.shape, want.shape)
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol, err_msg=name)
    print(f"ok: {name}")


# --- single conv, sweep of geometries, both schedules -----------------------
key = jax.random.PRNGKey(0)
for (k, s, p, c_in, c_out, h) in [
    (3, 1, 1, 3, 16, 64),     # VGG body
    (1, 1, 0, 8, 16, 32),     # pointwise
    (5, 1, 2, 4, 8, 64),      # 5x5 (paper-bug regime handled exactly)
    (7, 2, 3, 3, 16, 64),     # ResNet/EfficientNet stem
    (3, 2, 1, 8, 8, 64),      # strided 3x3
    (2, 2, 0, 4, 4, 32),      # pool-like conv
]:
    kp, kx, key = (*jax.random.split(key, 2), key)
    params = conv_params(kp, k, c_in, c_out)
    x = jax.random.normal(kx, (2, h, h, c_in))
    want = conv2d(x, params, stride=s, padding=[(p, p), (p, p)])
    for overlap in (False, True):
        fn = shard_map(
            partial(conv2d_spatial, k=k, s=s, p=p, axis_name="sp", overlap=overlap),
            mesh=mesh,
            in_specs=(P(None, "sp", None, None), P()),
            out_specs=P(None, "sp", None, None),
        )
        got = fn(x, params)
        check(f"conv k{k}s{s}p{p} overlap={overlap}", got, want)

# --- depthwise conv (EfficientNet / ConvNeXt path) --------------------------
kp, kx, key = (*jax.random.split(key, 2), key)
c = 8
params = conv_params(kp, 7, c, c, groups=c)
x = jax.random.normal(kx, (1, 56, 56, c))
want = conv2d(x, params, stride=1, padding=[(3, 3), (3, 3)], groups=c)
fn = shard_map(
    partial(conv2d_spatial, k=7, s=1, p=3, axis_name="sp", overlap=True, groups=c),
    mesh=mesh,
    in_specs=(P(None, "sp", None, None), P()),
    out_specs=P(None, "sp", None, None),
)
check("depthwise 7x7", fn(x, params), want)

# --- max pool ----------------------------------------------------------------
x = jax.random.normal(key, (2, 64, 64, 4))
want = max_pool(x, 2, 2)
fn = shard_map(
    partial(max_pool_spatial, k=2, s=2, axis_name="sp"),
    mesh=mesh,
    in_specs=P(None, "sp", None, None),
    out_specs=P(None, "sp", None, None),
)
check("maxpool 2x2", fn(x), want)

# --- full VGG feature extractor under shard_map ------------------------------
cfg = vgg.VGGConfig(img_res=64, width_mult=0.125, num_classes=10)
params = vgg.init(jax.random.PRNGKey(3), cfg)
x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, 64, 3))
want = vgg.features(params, cfg, x)


def spatial_features(x, feats):
    geom = cfg.geom()
    for p_l, g in zip(feats, geom.layers):
        if g.kind == "pool":
            x = max_pool_spatial(x, g.k, g.s, axis_name="sp")
        else:
            x = relu(conv2d_spatial(x, p_l, g.k, g.s, g.p, axis_name="sp", overlap=True))
    return x


fn = shard_map(
    spatial_features,
    mesh=mesh,
    in_specs=(P(None, "sp", None, None), P()),
    out_specs=P(None, "sp", None, None),
)
# 64 rows / 8 devices = 8 rows per shard; after 4 pools the shard is 4/8... the
# last block would underflow 1 row/shard -> run on the first 3 blocks instead.
cfg_sp = vgg.VGGConfig(img_res=64, width_mult=0.125, num_classes=10,
                       blocks=((2, 64), (2, 128), (3, 256)))
params_sp = vgg.init(jax.random.PRNGKey(3), cfg_sp)
want_sp = vgg.features(params_sp, cfg_sp, x)


def spatial_features_sp(x, feats):
    geom = cfg_sp.geom()
    for p_l, g in zip(feats, geom.layers):
        if g.kind == "pool":
            x = max_pool_spatial(x, g.k, g.s, axis_name="sp")
        else:
            x = relu(conv2d_spatial(x, p_l, g.k, g.s, g.p, axis_name="sp", overlap=True))
    return x


fn = shard_map(
    spatial_features_sp,
    mesh=mesh,
    in_specs=(P(None, "sp", None, None), P()),
    out_specs=P(None, "sp", None, None),
)
check("vgg features (3 blocks, 8-way SP)", fn(x, params_sp["features"]), want_sp)

print("ALL MULTIDEV SPATIAL CHECKS PASSED")

# --- pipeline parallelism over 8 stages --------------------------------------
from repro.parallel.pipeline import pipeline_apply

S = 8
D = 16
M = 6
key = jax.random.PRNGKey(7)
ws = jax.random.normal(key, (S, D, D)) * 0.3
xs = jax.random.normal(jax.random.PRNGKey(8), (M, 4, D))

def stage_fn(w, x):
    return jnp.tanh(x @ w)

# reference: sequential through all stages
ref = xs
for i in range(S):
    ref = jax.vmap(lambda mb: stage_fn(ws[i], mb))(ref)

pipe = shard_map(
    lambda w, x: pipeline_apply(w[0], x, stage_fn, "sp"),  # drop the stage dim
    mesh=mesh,
    in_specs=(P("sp"), P()),       # one stage's weights per device
    out_specs=P(),                  # outputs valid on the last stage
    check_rep=False,
)
got = pipe(ws, xs)
check("pipeline 8-stage forward", got, ref, tol=1e-4)

print("ALL MULTIDEV CHECKS PASSED (incl. pipeline)")
