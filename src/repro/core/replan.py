"""Online joint compute+link adaptive re-planning: estimate, bucket, cache,
re-optimise.

The paper's §V.D evaluates HALP under a *time-variant* offloading channel but
still runs one plan chosen offline against nominal rates; DistrEdge
(arXiv 2202.01699) and the authors' own prototype (arXiv 2211.13778) show the
remaining latency on real testbeds comes from exactly that gap -- measured
link rates AND measured per-device compute rates both drift away from the
nominals the partition was optimised for (a straggling secondary stretches
every makespan just like a collapsed link).  This module closes the loop
online, in three layers:

* :class:`LinkRateEstimator` -- an EWMA over observed per-link transfer times
  ``rate_sample = 8 * nbytes / elapsed``, seeded from the
  :class:`~repro.core.topology.CollabTopology` nominals, one estimate per
  directed host<->secondary pair (secondaries never talk directly, so 2N
  links suffice; any other measured pair -- e.g. the IoT offload uplink of an
  :class:`~repro.core.reliability.OffloadChannel` -- can be folded in through
  the same ``observe``).  Its compute-side mirror is
  :class:`ComputeRateEstimator`: an EWMA over observed per-ES execution
  times ``rate_sample = flops / elapsed``, seeded from each
  :class:`~repro.core.topology.Platform`'s calibrated ``eff_flops`` and fed
  by the runtime's straggler stats (``runtime.fault``) and the serving
  engine's per-ES timing hook (``runtime.serve``).

* :class:`PlanCache` -- an LRU map from **(topology fingerprint + optimiser
  config, quantised rate buckets)** to the
  :class:`~repro.core.optimizer.OptimizeResult`
  for that operating point.  Link rates are quantised into geometric bands of
  width ``bucket_frac`` (30% by default): every rate inside a band maps to the
  same key, and the plan is optimised against the band's *representative*
  (geometric centre) rate, so cache entries are reproducible regardless of
  which measured rate first filled them.  Compute rates are quantised into
  geometric bands **anchored at each ES's nominal** (:func:`compute_bucket`):
  band 0's representative is *exactly* the calibrated ``eff_flops``, so a
  controller whose compute never drifts optimises against the nominal
  platforms and serves plans identical to the link-only controller's --
  compute adaptivity is free until a straggler actually appears.  The
  per-ES ``eff_flops`` therefore lives in the bucketed key space (as the
  band anchor), NOT in :func:`topology_fingerprint`: revisited compute
  operating points amortise through the cache exactly like revisited channel
  bands.  In steady state -- mean-reverting conditions revisiting a handful
  of bands -- every plan request is an O(1) dict hit.

* :class:`ReplanController` -- the policy.  Each control epoch it re-buckets
  the current estimates (link and compute jointly) and applies a **shared
  hysteresis**: the estimates must sit outside the active bands -- on any
  link or any ES -- for ``hysteresis`` consecutive epochs before the latest
  bucket key becomes active (a single-epoch excursion therefore cannot
  thrash the plan, at the cost of reacting ``hysteresis - 1`` epochs late; a
  steadily drifting condition is not starved).  Only when the active key
  changes does the controller consult the cache, and only on a cache miss
  does it rebuild the :class:`CollabTopology` with the band-representative
  link rates (:meth:`~repro.core.topology.CollabTopology.with_links`) and
  platforms (:meth:`~repro.core.topology.CollabTopology.with_platforms`) and
  invoke :func:`~repro.core.optimizer.optimize_plan`.  Setting
  ``bucket_frac=0`` keys on the exact estimates (every drift is a miss): that
  degenerate configuration is the "always re-plan" upper-baseline used by
  ``benchmarks/replan_sweep.py``; ``ReplanConfig(adapt_compute=False)`` keeps
  the PR-2 link-only behaviour (compute estimates frozen at the nominals) --
  the baseline ``benchmarks/straggler_sweep.py`` measures joint adaptation
  against.

The re-optimisation objective defaults to the discrete-event simulator (the
repo's ground truth); ``ReplanConfig(use_simulator=False)`` switches to the
paper's closed-form recursion (:func:`~repro.core.schedule.halp_closed_form`),
which prices the same event topology ~two orders of magnitude faster but, for
``n_tasks > 1``, over-weights communication (see :class:`ReplanConfig`).
Plans produced here are geometry-only (row partitions), so a plan optimised
for estimated rates is always *valid* (lossless) under the true rates -- only
its latency is at stake.  ``runtime.serve`` consumes the controller through
:func:`~repro.runtime.serve.plan_aware_batch_size`, which feeds the *current*
plan's predicted makespan into ``choose_batch_size``.
"""
from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict
from dataclasses import dataclass
from typing import Mapping

import numpy as np

from .events import simulate_scheme
from .nets import ConvNetGeom
from .optimizer import OptimizeResult, optimize_plan
from .partition import HALPPlan, SCHEME_HALO, SchemePlan
from .schedule import halp_closed_form
from .topology import CollabTopology, Link

__all__ = [
    "FINGERPRINT_EXCLUDED",
    "LinkRateEstimator",
    "ComputeRateEstimator",
    "PlanCache",
    "ReplanConfig",
    "ReplanController",
    "StaticPlanner",
    "optimize_static",
    "topology_fingerprint",
    "rate_bucket",
    "bucket_rate",
    "compute_bucket",
    "compute_band_flops",
]

# Reference rate for the geometric bucket grid.  Any positive constant works
# (it only shifts bucket indices); 1 Mbps keeps indices small and readable for
# both Mbps offload channels and Gbps ES-ES links.
BUCKET_REF_BPS = 1e6


def rate_bucket(rate_bps: float, bucket_frac: float) -> float:
    """Quantise a rate into a geometric band index of width ``bucket_frac``.

    Band ``i`` covers ``[REF * (1+f)^i, REF * (1+f)^(i+1))``; with the default
    f = 0.3 two rates land in the same band iff they differ by < 30%.
    ``bucket_frac <= 0`` disables quantisation and returns the exact rate
    (the always-replan degenerate keying)."""
    if rate_bps <= 0:
        raise ValueError(f"rate must be positive, got {rate_bps}")
    if bucket_frac <= 0:
        return rate_bps
    return math.floor(math.log(rate_bps / BUCKET_REF_BPS) / math.log1p(bucket_frac))


def bucket_rate(bucket: float, bucket_frac: float) -> float:
    """The band's representative rate (geometric centre) -- the rate plans are
    optimised against, so a band's cached plan is independent of which
    measured rate first triggered it."""
    if bucket_frac <= 0:
        return bucket  # exact keying: the "bucket" is the rate itself
    return BUCKET_REF_BPS * (1.0 + bucket_frac) ** (bucket + 0.5)


def compute_bucket(rate_flops: float, nominal_flops: float, bucket_frac: float) -> float:
    """Quantise an effective-compute estimate into a geometric band of width
    ``bucket_frac``, anchored at the ES's calibrated nominal.

    Band ``i`` is centred on ``nominal * (1+f)^i`` (round-to-nearest in log
    space), so band 0 covers ``nominal * (1+f)^(-1/2) .. nominal * (1+f)^(1/2)``
    and -- unlike the floor-based link grid of :func:`rate_bucket` -- the
    *seed estimate itself sits exactly on its band's representative*.  A
    controller whose compute never drifts therefore optimises against the
    nominal ``eff_flops`` bit-for-bit, preserving plan equality with the
    link-only path; see :func:`compute_band_flops`.  ``bucket_frac <= 0``
    disables quantisation and returns the exact estimate (the always-replan
    degenerate keying)."""
    if rate_flops <= 0 or nominal_flops <= 0:
        raise ValueError(f"need positive rates, got {rate_flops}, {nominal_flops}")
    if bucket_frac <= 0:
        return rate_flops
    return round(math.log(rate_flops / nominal_flops) / math.log1p(bucket_frac))


def compute_band_flops(bucket: float, nominal_flops: float, bucket_frac: float) -> float:
    """The compute band's representative effective FLOP/s -- what plans are
    optimised against.  Band 0 maps back to the nominal *exactly* (not merely
    within the band), which is what keeps an undrifted joint controller
    bit-identical to the link-only controller."""
    if bucket_frac <= 0:
        return bucket  # exact keying: the "bucket" is the estimate itself
    return nominal_flops * (1.0 + bucket_frac) ** bucket


def topology_fingerprint(topology: CollabTopology) -> tuple:
    """Hashable identity of everything the optimum depends on *except* rates:
    the host/secondary names in order.

    Per-ES effective compute is deliberately NOT part of the fingerprint
    anymore: like link rates, ``eff_flops`` is an online-estimated quantity
    and lives in the bucketed key space (as each ES's band anchor plus band
    index -- see :func:`compute_bucket`), so the :class:`PlanCache` amortises
    across revisited compute operating points instead of pinning one compute
    level per cluster."""
    return (topology.host, topology.secondaries)


class _EwmaRateEstimator:
    """Shared EWMA machinery of the link/compute estimators: a dict of rate
    estimates seeded from nominals, each observation folding ``alpha`` of the
    way toward the new sample."""

    def __init__(self, nominal: Mapping, alpha: float = 0.4):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self._rates = dict(nominal)

    def _fold(self, key, sample: float) -> float:
        prev = self._rates.get(key)
        est = sample if prev is None else (1.0 - self.alpha) * prev + self.alpha * sample
        self._rates[key] = est
        return est

    def rates(self) -> dict:
        return dict(self._rates)


class LinkRateEstimator(_EwmaRateEstimator):
    """EWMA per-link rate estimates from observed transfer times.

    Each observation ``(src, dst, nbytes, elapsed_s)`` yields a rate sample
    ``8 * nbytes / elapsed_s``; the estimate moves ``alpha`` of the way toward
    it.  Estimates are seeded from nominal rates, so before any traffic a
    controller optimises for the nominal rates' *bands* (representative rates
    within ``bucket_frac`` of the nominals -- close to, but not necessarily
    identical with, the offline nominal-rate plan)."""

    @classmethod
    def from_topology(cls, topology: CollabTopology, alpha: float = 0.4) -> "LinkRateEstimator":
        """Seed one estimate per directed host<->secondary link from nominals."""
        return cls(
            {pair: topology.link_between(*pair).rate_bps for pair in topology.collab_pairs()},
            alpha=alpha,
        )

    def observe(self, src: str, dst: str, nbytes: float, elapsed_s: float) -> float:
        """Fold one observed transfer in; returns the updated estimate."""
        if nbytes <= 0 or elapsed_s <= 0:
            raise ValueError(f"need positive bytes/elapsed, got {nbytes}, {elapsed_s}")
        return self._fold((src, dst), 8.0 * nbytes / elapsed_s)

    def rate(self, src: str, dst: str) -> float:
        return self._rates[(src, dst)]


class ComputeRateEstimator(_EwmaRateEstimator):
    """EWMA per-ES effective-compute estimates from observed execution times.

    The compute-side mirror of :class:`LinkRateEstimator`: each observation
    ``(es, flops, elapsed_s)`` -- one timed compute chunk of known FLOP count
    on one ES -- yields a rate sample ``flops / elapsed_s`` (effective
    FLOP/s) and moves that ES's estimate ``alpha`` of the way toward it.
    Estimates are seeded from each :class:`~repro.core.topology.Platform`'s
    calibrated ``eff_flops`` (host and secondaries alike), so an ES that is
    never measured keeps behaving like its nominal.  Feeders: the runtime's
    straggler tracking (:class:`~repro.runtime.fault.FaultTolerantTrainer`'s
    ``compute_observer`` hook) and the serving engine's per-ES timing hook
    (:meth:`~repro.runtime.serve.BatchingEngine.observe_es_time`)."""

    @classmethod
    def from_topology(cls, topology: CollabTopology, alpha: float = 0.4) -> "ComputeRateEstimator":
        """Seed one estimate per ES (host included) from the platform nominals."""
        return cls(
            {es: topology.platform_of(es).eff_flops for es in topology.es_names},
            alpha=alpha,
        )

    def observe(self, es: str, flops: float, elapsed_s: float) -> float:
        """Fold one observed execution in; returns the updated estimate."""
        if flops <= 0 or elapsed_s <= 0:
            raise ValueError(f"need positive flops/elapsed, got {flops}, {elapsed_s}")
        return self._fold(es, flops / elapsed_s)

    def observe_samples(self, samples) -> dict[str, float]:
        """Fold an iterable of ``(es, flops, elapsed_s)`` samples -- the exact
        triples the serving executor's timing attribution emits
        (``run_plan(..., time_observer=...)`` /
        ``benchmarks/spatial_calibration.py``).  Returns the updated per-ES
        estimates for the ESs observed."""
        seen: dict[str, float] = {}
        for es, flops, elapsed_s in samples:
            seen[es] = self.observe(es, flops, elapsed_s)
        return seen

    def rate(self, es: str) -> float:
        return self._rates[es]


class PlanCache:
    """LRU cache of optimisation results keyed on (fingerprint, buckets),
    where the fingerprint covers the cluster *and* the optimiser config.

    ``get`` / ``put`` are O(1); ``hits``/``misses``/``evictions`` make the
    amortisation claim measurable (``benchmarks/replan_sweep.py`` asserts a
    >= 90% steady-state hit rate).

    With a persistent ``store`` (:class:`~repro.core.planstore.PlanStore`)
    attached, the cache becomes the in-memory front of a two-tier read-through
    / write-through hierarchy: a memory miss falls through to the store (a
    store hit is promoted into the LRU *without* a write-back and counted in
    both ``hits`` and ``store_hits``), ``put`` writes both tiers, and LRU
    eviction only drops the memory copy -- the store keeps every plan ever
    optimised, so restarts and sibling processes warm-start from it.
    ``peek`` stays memory-only by design: the serving path peeks per
    admission decision, and hammering sqlite from that loop would buy nothing
    (the active entry is always resident after its first ``get``)."""

    def __init__(self, capacity: int = 128, store=None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.store = store
        self._entries: OrderedDict[tuple, OptimizeResult] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.store_hits = 0  # memory misses served by the persistent tier

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: tuple) -> OptimizeResult | None:
        entry = self._entries.get(key)
        if entry is not None:
            self._entries.move_to_end(key)
            self.hits += 1
            return entry
        if self.store is not None:
            entry = self.store.get(key)
            if entry is not None:
                self._insert(key, entry)
                self.hits += 1
                self.store_hits += 1
                return entry
        self.misses += 1
        return None

    def peek(self, key: tuple) -> OptimizeResult | None:
        """Read the *memory tier only*, without touching hit/miss counters or
        the LRU order.  The serving path (latency predictions, admission
        control) peeks, so the telemetry keeps counting *plan requests per
        control epoch* -- the quantity the amortisation claim is stated in --
        rather than being swamped by per-admission lookups."""
        return self._entries.get(key)

    def put(self, key: tuple, result: OptimizeResult, provenance: dict | None = None) -> None:
        self._insert(key, result)
        if self.store is not None:
            self.store.put(key, result, provenance=provenance)

    def _insert(self, key: tuple, result: OptimizeResult) -> None:
        """Memory-tier insert with LRU eviction (never touches the store)."""
        self._entries[key] = result
        self._entries.move_to_end(key)
        if len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def entries(self) -> list[OptimizeResult]:
        """All cached results, least- to most-recently used (e.g. for
        verifying every plan a controller ever served stays lossless)."""
        return list(self._entries.values())


# Every ReplanConfig field is either folded into ReplanController._fingerprint
# (it changes which plan a cache/store key maps to) or named here with the
# reason it may NOT key.  The partition is machine-checked by
# repro.analysis.keying_lint: adding a config field without fingerprinting it
# or justifying its exclusion is a CI failure -- the silent-stale-plan bug
# class (two controllers differing in an unkeyed knob sharing wrong store
# entries) cannot land unnoticed.
FINGERPRINT_EXCLUDED: dict[str, str] = {
    "engine": "batched and scalar candidate pricing return bit-identical "
    "plans (pinned in tests/test_conformance.py), so both engines share one "
    "cache entry by design",
    "adapt_compute": "gates whether bucket keys *move* under compute drift, "
    "never what plan a given key maps to; frozen and adaptive controllers "
    "share entries by design",
    "alpha": "estimator-side EWMA smoothing: it changes when a band boundary "
    "is crossed, not the plan either band maps to (bands key via the bucket "
    "part of the cache key)",
    "hysteresis": "adoption timing only: how many epochs a drift must persist "
    "before the active key switches; the key->plan mapping is untouched",
}


@dataclass(frozen=True)
class ReplanConfig:
    """Knobs of the online re-planner (see the module docstring for design)."""

    bucket_frac: float = 0.3  # geometric band width; <= 0 keys on exact rates
    hysteresis: int = 2  # consecutive epochs outside the active bands to adopt
    alpha: float = 0.4  # EWMA weight of the rate estimators (link and compute)
    n_tasks: int = 4  # concurrent tasks the plan is optimised for
    # Joint compute+link adaptation.  False freezes the compute estimates at
    # the platform nominals (the PR-2 link-only controller, kept as the
    # baseline benchmarks/straggler_sweep.py measures joint adaptation
    # against): observe_compute becomes a no-op, so compute buckets never
    # switch and only channel drift triggers re-planning.  This knob is NOT
    # part of the cache fingerprint: it only gates whether keys *move*, never
    # what plan a given key maps to, so adaptive and frozen controllers can
    # share cache entries by design.
    adapt_compute: bool = True
    overlap_choices: tuple[int, ...] = (2, 4, 6, 8)
    max_rounds: int = 6  # coordinate-descent budget per re-optimisation
    # Candidate-pricing engine for cache-miss re-optimisations.  "batched"
    # (the DAG-template + vectorized-DES fast path) and "scalar" return
    # bit-identical plans; the knob exists so benchmarks can price the miss
    # path both ways.  Misses therefore pay the fast path by default.
    engine: str = "batched"
    # Hard planner-latency bounds for the miss path (None/0.0 = unbounded):
    # eval_budget caps priced candidates per optimize_plan call, tol stops a
    # replan once a descent round improves the makespan by less than this.
    eval_budget: int | None = None
    tol: float = 0.0
    # Objective engine.  The DES is the repo's ground truth and the default:
    # the closed form prices each secondary slot's uplink as shared across
    # tasks (eq. 17's x n_tasks) while the DES models the paper's multi-task
    # deployment of N * n_tasks distinct secondaries with their own links, so
    # for n_tasks > 1 the closed form over-weights communication and
    # over-shrinks slow-link segments.  Set False for the ~20x cheaper
    # closed-form search when the re-plan latency budget is tight (it stays a
    # safe choice for single-task controllers, where the two engines agree).
    use_simulator: bool = True
    # Per-stage partitioning-scheme vocabulary handed to the optimizer.  The
    # halo-only default keeps every miss on the legacy search (bit-identical
    # plans).  A larger vocabulary enlarges the searched space, so it IS part
    # of the cache fingerprint -- two controllers with different vocabularies
    # must never share an entry, while the pricing `engine` still does not
    # key (bit-identical scores either way).  Scheme vocabularies beyond the
    # default require the DES objective (the closed form is halo-only), so
    # use_simulator=False with a non-trivial vocabulary raises.
    schemes: tuple[str, ...] = (SCHEME_HALO,)


def _optimize_against(
    net: ConvNetGeom, topology: CollabTopology, config: ReplanConfig
) -> OptimizeResult:
    """One plan optimisation against the given topology's rates."""
    objective = None
    if not config.use_simulator and tuple(config.schemes) != (SCHEME_HALO,):
        raise ValueError(
            "use_simulator=False prices through the halo-only closed form; "
            f"the scheme vocabulary {tuple(config.schemes)} needs the DES "
            "objective (use_simulator=True)"
        )
    if not config.use_simulator:

        def objective(ratios: tuple[float, ...], w: int) -> float:
            try:
                return halp_closed_form(
                    net,
                    topology=topology,
                    ratios=ratios,
                    overlap_rows=w,
                    n_tasks=config.n_tasks,
                )["total"]
            except (AssertionError, ValueError):
                return float("inf")

    return optimize_plan(
        net,
        topology,
        n_tasks=config.n_tasks,
        overlap_choices=config.overlap_choices,
        max_rounds=config.max_rounds,
        objective=objective,
        engine=config.engine,
        eval_budget=config.eval_budget,
        tol=config.tol,
        schemes=tuple(config.schemes),
    )


def optimize_static(
    net: ConvNetGeom, topology: CollabTopology, config: ReplanConfig = ReplanConfig()
) -> OptimizeResult:
    """The offline baseline: optimise once against *nominal* rates.

    Uses the same objective/budget as :class:`ReplanController`, so benchmark
    comparisons isolate adaptivity rather than optimiser settings."""
    return _optimize_against(net, topology, config)


class StaticPlanner:
    """Planner-protocol wrapper around one fixed plan (the paper's baseline):
    ignores all observations (link and compute), serves the same plan every
    epoch."""

    def __init__(self, plan: HALPPlan):
        self._plan = plan

    def observe_transfer(self, src: str, dst: str, nbytes: float, elapsed_s: float) -> None:
        pass

    def observe_compute(self, es: str, flops: float, elapsed_s: float) -> None:
        pass

    def plan_for_epoch(self) -> HALPPlan:
        return self._plan


class ReplanController:
    """Joint compute+link adaptive planner: EWMA estimates -> buckets ->
    shared hysteresis -> cached :func:`optimize_plan`.

    Implements the same planner protocol as :class:`StaticPlanner`
    (``observe_transfer`` + ``observe_compute`` + ``plan_for_epoch``), so
    :func:`~repro.core.simulator.replay_trace` and the serving loop drive
    either interchangeably.

    Subclasses may override :meth:`_optimize` to swap what is recomputed on a
    bucket switch (e.g. :class:`~repro.core.placement.PlacementController`
    re-places *every task* instead of re-optimising one shared plan); the
    estimator, bucketing, hysteresis, cache, and telemetry are inherited
    unchanged.  ``_cache_kind`` namespaces cache keys so different controller
    kinds can share one :class:`PlanCache`."""

    _cache_kind = "plan"

    def __init__(
        self,
        net: ConvNetGeom,
        topology: CollabTopology,
        config: ReplanConfig = ReplanConfig(),
        cache: PlanCache | None = None,
        store=None,
    ):
        self.net = net
        self.nominal = topology
        self.config = config
        # store= attaches a persistent tier (core.planstore.PlanStore): a
        # restarted controller then serves previously-optimised operating
        # points with zero optimizer calls (warm start), and controllers in
        # other processes sharing the same store file inherit them too.
        self.cache = cache if cache is not None else PlanCache(store=store)
        if store is not None and self.cache.store is None:
            self.cache.store = store
        self.estimator = LinkRateEstimator.from_topology(topology, alpha=config.alpha)
        self.compute_estimator = ComputeRateEstimator.from_topology(
            topology, alpha=config.alpha
        )
        # per-ES band anchors of the compute grid (the calibrated nominals)
        self._nominal_flops = {
            es: topology.platform_of(es).eff_flops for es in topology.es_names
        }
        # identity of everything a cached optimum depends on besides the rate
        # buckets: the cluster and every optimiser-facing config knob (bucket
        # indices are grid-relative, so bucket_frac in particular must key) --
        # controllers with different configs can then share one PlanCache.
        # eff_flops is NOT here: it keys through the compute part of the
        # bucket key (anchor + band index), and adapt_compute only gates
        # whether keys move, never what a key maps to.
        self._fingerprint = (
            self._cache_kind,
            topology_fingerprint(topology),
            config.bucket_frac,
            config.n_tasks,
            tuple(config.overlap_choices),
            config.max_rounds,
            config.use_simulator,
            # search-bounding knobs change which plan a miss produces, so they
            # must key; the pricing engine does NOT (bit-identical scores) --
            # batched and scalar controllers share entries by design
            config.eval_budget,
            config.tol,
            # the scheme vocabulary changes the searched space (and hence the
            # plan a miss produces), so it keys like the search bounds do
            tuple(config.schemes),
        )
        self._active = self._bucket_key()
        self._pending_count = 0  # consecutive epochs spent outside the active bands
        # telemetry
        self.epochs = 0
        self.replans = 0  # adopted bucket switches
        self.optimizer_calls = 0
        self._calibration = 1.0  # measured/predicted latency EWMA (serving)
        # (fingerprint, active key, batch) -> raw predicted latency; the
        # serving loop prices whole latency *tables* per operating point, so
        # repeat pricing of the same point must be a dict hit
        self._latency_memo: dict[tuple, float] = {}

    # -- bucketing ------------------------------------------------------------

    def _bucket_key(self) -> tuple:
        """The joint operating point: quantised link bands + quantised compute
        bands.  The compute part carries each ES's band *anchor* (its nominal
        ``eff_flops``) alongside the band index, so the key alone determines
        the representative platform -- controllers over different-speed
        clusters can share one cache without colliding."""
        f = self.config.bucket_frac
        links = tuple(
            sorted((pair, rate_bucket(r, f)) for pair, r in self.estimator.rates().items())
        )
        noms = self._nominal_flops
        compute = tuple(
            sorted(
                (es, noms[es], compute_bucket(r, noms[es], f))
                for es, r in self.compute_estimator.rates().items()
            )
        )
        return (links, compute)

    def estimated_topology(self) -> CollabTopology:
        """The nominal topology rebuilt with the active bands' representative
        link rates and per-ES platforms -- what plans are optimised against.
        Undrifted ESs sit in compute band 0, whose representative is exactly
        the nominal ``eff_flops`` (see :func:`compute_bucket`)."""
        f = self.config.bucket_frac
        link_part, compute_part = self._active
        links = {pair: Link(bucket_rate(b, f)) for pair, b in link_part}
        platforms = {
            es: dataclasses.replace(
                self.nominal.platform_of(es),
                eff_flops=compute_band_flops(b, nom, f),
            )
            for es, nom, b in compute_part
        }
        return self.nominal.with_links(links).with_platforms(platforms)

    # -- planner protocol -----------------------------------------------------

    def observe_transfer(self, src: str, dst: str, nbytes: float, elapsed_s: float) -> float:
        """Feed one observed transfer into the link-rate estimator."""
        return self.estimator.observe(src, dst, nbytes, elapsed_s)

    def observe_compute(self, es: str, flops: float, elapsed_s: float) -> float:
        """Feed one observed per-ES execution (a timed compute chunk of known
        FLOP count) into the compute-rate estimator.  With
        ``config.adapt_compute=False`` the sample is dropped (estimates stay
        at the nominals -- the link-only baseline), but the arguments are
        still validated so mis-wired feeders fail loudly either way."""
        if es not in self._nominal_flops:
            raise ValueError(f"{es!r} is not an ES of this controller's topology")
        if not self.config.adapt_compute:
            if flops <= 0 or elapsed_s <= 0:
                raise ValueError(f"need positive flops/elapsed, got {flops}, {elapsed_s}")
            return self.compute_estimator.rate(es)
        return self.compute_estimator.observe(es, flops, elapsed_s)

    def step(self) -> bool:
        """Advance one control epoch; returns True iff the active bucket key
        switched (i.e. the serving plan may change).

        Hysteresis: the estimates must sit *outside* the active bands for
        ``hysteresis`` consecutive epochs (<= 1 means immediately) before the
        most recent candidate key is adopted; wandering back inside the
        active bands resets the counter.  Counting epochs-away-from-active
        (rather than epochs-on-one-candidate) means a channel drifting
        monotonically across one band per epoch still replans after the
        hysteresis lag instead of being starved by its own motion."""
        self.epochs += 1
        candidate = self._bucket_key()
        if candidate == self._active:
            self._pending_count = 0
            return False
        self._pending_count += 1
        if self._pending_count < max(1, self.config.hysteresis):
            return False
        self._active = candidate
        self._pending_count = 0
        self.replans += 1
        # a bucket switch retires every latency-memo entry priced at another
        # operating point; without this the memo grows one latency table per
        # bucket key ever visited over a long-running controller
        self._latency_memo = {
            k: v for k, v in self._latency_memo.items() if k[1] == candidate
        }
        return True

    def _optimize(self, topology: CollabTopology) -> OptimizeResult:
        """Recompute the operating point for ``topology`` (cache-miss path).
        Subclasses override this to re-place instead of re-plan."""
        return _optimize_against(self.net, topology, self.config)

    def current(self) -> OptimizeResult:
        """The active operating point's plan: an O(1) cache hit in steady
        state, a fresh :meth:`_optimize` run on a miss.

        This is the *per-epoch* entry point and the one place hit/miss
        telemetry is counted; out-of-epoch reads (``plan``, ``makespan``, the
        serving integration) go through :meth:`_active_result` instead."""
        key = (self._fingerprint, self._active)
        result = self.cache.get(key)
        if result is None:
            topology = self.estimated_topology()
            result = self._optimize(topology)
            self.optimizer_calls += 1
            self.cache.put(key, result, provenance=self._provenance(topology, result))
        return result

    def _provenance(self, topology: CollabTopology, result: OptimizeResult) -> dict:
        """What a freshly-optimised entry was computed against -- the band
        representatives, not the raw measurements (the measurements that led
        here are not part of the key, so recording them would be misleading).
        Persisted verbatim by the store tier; harmless when there is none."""
        return dict(
            kind=self._cache_kind,
            engine=self.config.engine,
            makespan=float(result.makespan),
            host=topology.host,
            link_rates_bps={
                f"{src}->{dst}": topology.link_between(src, dst).rate_bps
                for src, dst in topology.collab_pairs()
            },
            platform_eff_flops={
                es: topology.platform_of(es).eff_flops for es in topology.es_names
            },
        )

    def prime(self, bucket_key: tuple) -> OptimizeResult:
        """Fill the cache (and store, if attached) for an arbitrary operating
        point without adopting it: the offline entry point
        ``tools/precompute_plans.py`` uses to walk the bucket lattice with the
        controller's own keying/optimisation logic.  The active key, pending
        hysteresis count, and latency memo are left untouched."""
        saved = self._active
        self._active = bucket_key
        try:
            return self.current()
        finally:
            self._active = saved

    def _active_result(self) -> OptimizeResult:
        """The active plan without disturbing the epoch telemetry (peek);
        falls through to :meth:`current` only if the entry is genuinely
        absent (first request, or evicted)."""
        result = self.cache.peek((self._fingerprint, self._active))
        return result if result is not None else self.current()

    def plan_for_epoch(self) -> HALPPlan:
        """One control epoch: hysteresis step, then the (cached) active plan."""
        self.step()
        return self.current().plan

    @property
    def plan(self) -> HALPPlan:
        return self._active_result().plan

    @property
    def makespan(self) -> float:
        """Predicted makespan of the active plan at ``config.n_tasks``."""
        return self._active_result().makespan

    # -- serving integration --------------------------------------------------

    def _price_batch(self, batch_size: int) -> float:
        """Price the active operating point at ``batch_size`` concurrent
        tasks (closed form here; :class:`~repro.core.placement.\
PlacementController` overrides with the shared-secondary multi-task DES).
        Mixed-scheme plans have no closed form: they price through the scheme
        DES at ``n_tasks=batch_size`` instead."""
        plan = self._active_result().plan
        if isinstance(plan, SchemePlan):
            return simulate_scheme(
                self.net,
                self.estimated_topology(),
                ratios=plan.ratios,
                overlap_rows=plan.overlap_rows,
                assignment=plan.assignment,
                n_tasks=batch_size,
            )["total"]
        return halp_closed_form(
            self.net,
            topology=self.estimated_topology(),
            plan=plan,
            n_tasks=batch_size,
        )["total"]

    def _raw_predicted_latency(self, batch_size: int) -> float:
        """Memoised :meth:`_price_batch`: pure in (fingerprint, active bucket
        key, batch size), because ``estimated_topology`` and the active plan
        are both functions of the active key alone."""
        key = (self._fingerprint, self._active, batch_size)
        hit = self._latency_memo.get(key)
        if hit is None:
            hit = self._price_batch(batch_size)
            self._latency_memo[key] = hit
        return hit

    def predicted_latency(self, batch_size: int) -> float:
        """Closed-form makespan of the *current* plan for a batch of
        ``batch_size`` tasks, scaled by the measured-latency calibration --
        the latency model ``choose_batch_size`` admits batches against."""
        return self._raw_predicted_latency(batch_size) * self._calibration

    def latency_table(self, max_batch: int) -> np.ndarray:
        """The calibrated latency curve ``table[b-1] = predicted_latency(b)``
        for ``b = 1..max_batch`` -- one ready-made ``lat_table`` row for
        ``repro.runtime.serve.serve_trace``, priced at the controller's
        current operating point (re-extract after a bucket switch)."""
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        return np.array([self.predicted_latency(b) for b in range(1, max_batch + 1)])

    def observe_batch_latency(self, batch_size: int, elapsed_s: float) -> None:
        """Fold a measured batch latency back in: the ratio measured/predicted
        becomes an EWMA calibration factor on future predictions (clamped to
        [0.1, 10] so one outlier batch cannot poison admission control)."""
        if elapsed_s <= 0 or batch_size < 1:
            return
        predicted = self._raw_predicted_latency(batch_size)
        if predicted <= 0:
            return
        ratio = min(10.0, max(0.1, elapsed_s / predicted))
        a = self.config.alpha
        self._calibration = (1.0 - a) * self._calibration + a * ratio

    def stats(self) -> dict:
        out = dict(
            epochs=self.epochs,
            replans=self.replans,
            optimizer_calls=self.optimizer_calls,
            cache_hits=self.cache.hits,
            cache_misses=self.cache.misses,
            cache_entries=len(self.cache),
            cache_hit_rate=self.cache.hit_rate,
            calibration=self._calibration,
        )
        if self.cache.store is not None:
            # warm-start telemetry: how many plan requests the persistent
            # tier absorbed that would otherwise have been optimizer calls
            out["store_hits"] = self.cache.store_hits
            out["store_entries"] = len(self.cache.store)
        return out
