"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:
    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / ICI_link_bw
(The SPMD-partitioned module is per device, so dividing per-device quantities
by per-chip peaks equals the global/(chips x peak) form for balanced shards.)

MODEL_FLOPS uses 6*N_active*tokens (LM train), 2*N_active*tokens (inference),
and a measured single-device batch-1 forward for vision/diffusion (scaled by
batch, x3 for training, x steps for samplers).  The ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy waste.

TPU v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, "src")

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS = Path(__file__).parent / "dryrun_results"
FWD_CACHE = Path(__file__).parent / "dryrun_results" / "_fwd_flops.json"

CHIPS = {"pod16x16": 256, "pod2x16x16": 512}


def active_params(arch_name: str) -> float:
    """Parameters touched per token (dense count; MoE counts top_k/E of experts
    + shared; embeddings excluded per the standard 6ND convention)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get
    from repro.configs.steps import abstract_params

    arch = get(arch_name)
    p = abstract_params(arch, arch.cfg, jnp.bfloat16)
    cfg = arch.cfg
    total = 0.0
    flat = jax.tree_util.tree_flatten_with_path(p)[0]
    import numpy as np

    for path, leaf in flat:
        ps = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in path)
        n = float(np.prod(leaf.shape))
        if "embed" in ps and "label" not in ps:
            continue
        if "experts" in ps:
            n *= cfg.moe.top_k / cfg.moe.n_experts
        total += n
    return total


def _fwd_flops_b1(arch_name: str, cell_name: str) -> float:
    """Single-device, batch-1 forward HLO FLOPs for vision/diffusion cells."""
    cache = json.loads(FWD_CACHE.read_text()) if FWD_CACHE.exists() else {}
    key = f"{arch_name}__{cell_name}"
    if key in cache:
        return cache[key]
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.configs import get
    from repro.configs.steps import abstract_params, _adapt_vision_cfg, _diff_cfg

    arch = get(arch_name)
    cell = arch.cells[cell_name]
    res = cell.meta.get("img_res", getattr(arch.cfg, "img_res", None))
    if arch.family == "vision":
        cfg = _adapt_vision_cfg(arch, arch.cfg, res)
        x = jax.ShapeDtypeStruct((1, res, res, 3), jnp.bfloat16)
        fn = lambda p, x: arch.module.apply(p, cfg, x)
        args = (abstract_params(arch, cfg, jnp.bfloat16), x)
    else:  # diffusion: one denoiser forward at the cell resolution
        cfg = _diff_cfg(arch, arch.cfg, res)
        lr = cfg.latent_res
        lat = jax.ShapeDtypeStruct((1, lr, lr, cfg.latent_ch), jnp.bfloat16)
        t = jax.ShapeDtypeStruct((1,), jnp.int32)
        if arch.name.startswith("dit"):
            cond = jax.ShapeDtypeStruct((1,), jnp.int32)
        else:
            cond = jax.ShapeDtypeStruct((1, cfg.ctx_len, cfg.ctx_dim), jnp.bfloat16)
        fn = lambda p, l, tt, c: arch.module.apply(p, cfg, l, tt, c)
        args = (abstract_params(arch, cfg, jnp.bfloat16), lat, t, cond)
    lowered = jax.jit(fn).lower(*args)
    # trip-corrected accounting (these forwards scan their layer stacks too)
    from repro.launch.hlo_cost import analyze_hlo

    flops = analyze_hlo(lowered.compile().as_text()).flops
    cache[key] = flops
    FWD_CACHE.parent.mkdir(exist_ok=True, parents=True)
    FWD_CACHE.write_text(json.dumps(cache, indent=2))
    return flops


def model_flops(rec: dict) -> float:
    """Global useful FLOPs for the cell's step."""
    from repro.configs import get

    arch_name, cell_name = rec["arch"], rec["cell"]
    arch = get(arch_name)
    cell = arch.cells[cell_name]
    m = cell.meta
    if arch.family == "lm":
        n_act = active_params(arch_name)
        if cell.kind == "train":
            toks = m["global_batch"] * m["seq_len"]
            return 6.0 * n_act * toks
        if cell.kind == "prefill":
            return 2.0 * n_act * m["global_batch"] * m["seq_len"]
        if cell.kind == "decode":
            return 2.0 * n_act * m["global_batch"]
    fwd1 = _fwd_flops_b1(arch_name, cell_name)
    b = m.get("batch", 1)
    if cell.kind == "train":
        return 3.0 * fwd1 * b
    if cell.kind == "gen":
        return fwd1 * b * m.get("steps", 1)
    return fwd1 * b


def terms(rec: dict) -> dict:
    chips = CHIPS[rec["mesh"]]
    if "hlo_cost" in rec:  # while-trip-corrected accounting (preferred)
        f = rec["hlo_cost"]["flops"]
        by = rec["hlo_cost"]["bytes_accessed"]
        cb = rec["hlo_cost"]["collective_bytes"]
    else:  # raw XLA cost_analysis (scan bodies counted once -- under-reports)
        f = rec["cost"]["flops"]
        by = rec["cost"]["bytes_accessed"]
        cb = rec["collectives"]["total"]
    t_c = f / PEAK_FLOPS
    t_m = by / HBM_BW
    t_n = cb / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_n), key=lambda kv: kv[1])
    mf = model_flops(rec)
    hlo_global = f * chips
    # roofline fraction: time the *useful* model FLOPs would take at peak,
    # over the binding term.  1.0 = the step is pure useful compute at peak.
    t_useful = mf / chips / PEAK_FLOPS
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_n,
        "bottleneck": dom[0],
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "roofline_frac": t_useful / max(t_c, t_m, t_n, 1e-30),
    }


def load_all(mesh: str = "pod16x16") -> list[dict]:
    recs = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        if f.name.startswith("_"):
            continue
        recs.append(json.loads(f.read_text()))
    return recs


def table(mesh: str = "pod16x16") -> list[dict]:
    rows = []
    for rec in load_all(mesh):
        if rec["status"] != "ok":
            rows.append({**rec, "note": rec.get("skip_reason", rec.get("error", ""))[:60]})
            continue
        rows.append({**rec, **terms(rec)})
    return rows


def print_table(mesh: str = "pod16x16"):
    print(f"\n== Roofline terms per cell ({mesh}; v5e: 197TF bf16, 819GB/s HBM, 50GB/s ICI) ==")
    hdr = f"{'arch':22s} {'cell':12s} {'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} {'bound':>10s} {'useful':>7s} {'roofline':>8s}"
    print(hdr)
    for r in table(mesh):
        if r["status"] != "ok":
            print(f"{r['arch']:22s} {r['cell']:12s} {'-- ' + r['status'] + ': ' + r.get('note', '')}")
            continue
        print(
            f"{r['arch']:22s} {r['cell']:12s} {r['compute_s']:10.4f} {r['memory_s']:10.4f} "
            f"{r['collective_s']:10.4f} {r['bottleneck']:>10s} {r['useful_ratio']:7.2f} "
            f"{r['roofline_frac']:8.2f}"
        )
        print(f"roofline_{r['arch']}_{r['cell']}_{mesh},{r['compute_s']*1e6:.0f},{r['roofline_frac']:.3f}")


if __name__ == "__main__":
    for mesh in ("pod16x16", "pod2x16x16"):
        if list(RESULTS.glob(f"*__{mesh}.json")):
            print_table(mesh)
