"""AdamW from scratch (pytree-native), with optional low-precision moments.

``moment_dtype=bfloat16`` halves optimizer memory -- required to fit the
DeepSeek-671B config on a 512-chip v5e mesh (see DESIGN.md memory budget); the
update math still runs in f32.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = None  # None = param dtype


def adamw_init(params: Params, cfg: AdamWConfig) -> Params:
    def zeros_like(p):
        dt = cfg.moment_dtype or p.dtype
        return jnp.zeros(p.shape, dt)

    return {
        "mu": jax.tree_util.tree_map(zeros_like, params),
        "nu": jax.tree_util.tree_map(zeros_like, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(
    grads: Params,
    state: Params,
    params: Params,
    cfg: AdamWConfig,
    lr_scale: jax.Array | float = 1.0,
) -> tuple[Params, Params]:
    """Returns (new_params, new_state)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0

    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32) * clip
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        step = (mu32 / b1c) / (jnp.sqrt(nu32 / b2c) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, mu32.astype(mu.dtype), nu32.astype(nu.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(g, m, n, p) for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}
