"""VGG-16 (Simonyan & Zisserman, ICLR'15) -- the paper's evaluation model.

The feature extractor is expressed as an explicit layer list aligned with
``repro.core.nets.vgg16_geom`` so the HALP partitioner can drive it
layer-by-layer (``repro.spatial.partition_apply``); the classifier head runs
after the final merge, exactly as the paper's FLs do on the host ES.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from ..core.nets import ConvNetGeom, vgg16_geom
from ..core.rf import LayerGeom, conv as geom_conv, pool as geom_pool
from .common import Params, conv_params, dense_params, keygen
from .layers import conv2d, dense, max_pool, relu, global_avg_pool


@dataclass(frozen=True)
class VGGConfig:
    name: str = "vgg16"
    img_res: int = 224
    in_channels: int = 3
    num_classes: int = 1000
    width_mult: float = 1.0  # reduced configs for CPU smoke tests
    blocks: tuple[tuple[int, int], ...] = ((2, 64), (2, 128), (3, 256), (3, 512), (3, 512))
    fc_dims: tuple[int, ...] = (4096, 4096)

    def widths(self) -> list[tuple[int, int]]:
        return [(reps, max(8, int(c * self.width_mult))) for reps, c in self.blocks]

    def geom(self) -> ConvNetGeom:
        layers: list[LayerGeom] = []
        c_in = self.in_channels
        for b, (reps, c_out) in enumerate(self.widths(), start=1):
            for r in range(1, reps + 1):
                layers.append(geom_conv(f"conv{b}_{r}", c_in, c_out, k=3, s=1, p=1))
                c_in = c_out
            layers.append(geom_pool(f"pool{b}", c_in))
        final_rows = self.img_res // (2 ** len(self.blocks))
        c_last = self.widths()[-1][1]
        dims = [c_last * final_rows * final_rows, *self.fc_dims, self.num_classes]
        head = sum(2.0 * a + 0.0 for a in [])  # placeholder, computed below
        head = sum(2.0 * a * b for a, b in zip(dims[:-1], dims[1:]))
        return ConvNetGeom(
            name=self.name,
            in_rows=self.img_res,
            in_channels=self.in_channels,
            layers=tuple(layers),
            head_flops=head,
        )


def init(key: jax.Array, cfg: VGGConfig) -> Params:
    ks = keygen(key)
    feats: list[Params] = []
    c_in = cfg.in_channels
    for reps, c_out in cfg.widths():
        for _ in range(reps):
            feats.append(conv_params(next(ks), 3, c_in, c_out))
            c_in = c_out
        feats.append({})  # pool layer: no params (keeps indices aligned w/ geom)
    final_rows = cfg.img_res // (2 ** len(cfg.blocks))
    dims = [c_in * final_rows * final_rows, *cfg.fc_dims, cfg.num_classes]
    head = [dense_params(next(ks), a, b) for a, b in zip(dims[:-1], dims[1:])]
    return {"features": feats, "head": head}


def apply_layer(params: Params, geom: LayerGeom, x: jax.Array) -> jax.Array:
    """One feature layer on (a slice of) the input -- 'VALID' padded.

    The caller supplies exactly the input rows the receptive field requires
    (plus explicit zero padding at true tensor edges), so the layer itself uses
    VALID padding.  This is the primitive both the single-device reference and
    every distributed execution path share.
    """
    if geom.kind == "pool":
        return max_pool(x, k=geom.k, s=geom.s)
    y = conv2d(x, params, stride=geom.s, padding="VALID")
    return relu(y)


def features(params: Params, cfg: VGGConfig, x: jax.Array) -> jax.Array:
    geom = cfg.geom()
    for p, g in zip(params["features"], geom.layers):
        if g.kind != "pool" and g.p:
            x = jnp.pad(x, ((0, 0), (g.p, g.p), (g.p, g.p), (0, 0)))
        x = apply_layer(p, g, x)
    return x


def head(params: Params, x: jax.Array) -> jax.Array:
    x = x.reshape(x.shape[0], -1)
    hs = params["head"]
    for p in hs[:-1]:
        x = relu(dense(x, p))
    return dense(x, hs[-1])


def apply(params: Params, cfg: VGGConfig, x: jax.Array) -> jax.Array:
    """Full forward: feature extractor + classifier logits."""
    return head(params, features(params, cfg, x))
